"""Jit'd public wrappers around the Pallas kernels with oracle fallback.

ONE mode-dispatch layer for every kernel — resolution order:

  1. an explicit non-"auto" ``mode=`` argument;
  2. the ``REPRO_KERNEL_MODE`` environment variable (when the call said
     "auto" — one switch flips the whole serving stack, no per-kernel
     hardcoded defaults);
  3. backend auto-detect: real compiled kernel on TPU, pure-jnp oracle
     everywhere else (fast CPU path).

Accepted modes (aliases in parentheses):
  * "auto"                      — the detection above
  * "kernel" ("tpu")            — pallas kernel compiled for the backend
  * "kernel_interpret" ("interpret") — pallas kernel body interpreted in
                                  Python (CPU validation; what the
                                  parity tests use)
  * "ref" ("oracle")            — pure-jnp oracle

Paged entry points (``flash_decode_paged``, ``probe_and_topk``) read the
pool's pages IN PLACE through block tables / slot-cluster maps — no
compaction copy between ``memory/pool.py`` and the kernels; the dense
forms keep their pad-and-flatten prep for callers that hold dense slabs.
"""

from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_mod
from repro.kernels.centroid_probe import centroid_scores as _probe_kernel
from repro.kernels.flash_decode import flash_decode as _flash_kernel
from repro.kernels.flash_decode import flash_decode_paged as _flash_paged_kernel
from repro.kernels.ivf_topk import ivf_topk_flat as _ivf_kernel
from repro.kernels.probe_topk import probe_topk_fused as _probe_topk_kernel

DEFAULT_MODE = "auto"
MODE_ENV_VAR = "REPRO_KERNEL_MODE"
_ALIASES = {
    "auto": "auto",
    "ref": "ref", "oracle": "ref",
    "kernel": "kernel", "tpu": "kernel", "compiled": "kernel",
    "kernel_interpret": "kernel_interpret", "interpret": "kernel_interpret",
}


def resolve_mode(mode: Optional[str] = DEFAULT_MODE) -> str:
    """Resolve a requested mode to an execution plane ("ref" | "kernel"
    | "kernel_interpret"): explicit mode > ``REPRO_KERNEL_MODE`` env >
    backend auto-detect (TPU -> compiled kernel, else oracle)."""
    if mode is None:
        mode = "auto"
    if mode == "auto":
        mode = os.environ.get(MODE_ENV_VAR, "").strip().lower() or "auto"
    if mode not in _ALIASES:
        raise ValueError(
            f"unknown kernel mode {mode!r} (from {MODE_ENV_VAR}= or call "
            f"site); valid: {sorted(_ALIASES)}")
    resolved = _ALIASES[mode]
    if resolved == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "ref"
    return resolved


def _interpret(m: str) -> bool:
    return m == "kernel_interpret"


# kept for callers/tests that used the private resolver
_resolve = resolve_mode


def _pad_rows(x: jax.Array, multiple: int, fill=0):
    n = x.shape[0]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=fill)


def _divisor_tile(n: int, want: int) -> int:
    """Largest tile <= want that divides n (paged inputs are read in
    place, so the tile must divide instead of padding a copy)."""
    for t in range(min(want, n), 0, -1):
        if n % t == 0:
            return t
    return 1


def ivf_topk(pages: jax.Array, page_ids: jax.Array, page_mask: jax.Array,
             queries: jax.Array, k: int, *, tile: int = 1024,
             mode: str = DEFAULT_MODE) -> Tuple[jax.Array, jax.Array]:
    """Search the prefetch slab. pages [P,ps,d]; page_mask [P] or per-query
    [B,P]; queries [B,d] -> (scores [B,k], ids [B,k])."""
    m = resolve_mode(mode)
    if m == "ref":
        return ref_mod.ivf_topk_ref(pages, page_ids, page_mask, queries, k)
    B = queries.shape[0]
    P, ps, d = pages.shape
    flat = pages.reshape(P * ps, d)
    ids = page_ids.reshape(P * ps)
    if page_mask.ndim == 1:
        page_mask = jnp.broadcast_to(page_mask[None, :], (B, P))
    # tile must be a multiple of the page size and divide the padded slab
    tile = max(ps, (min(tile, P * ps) // ps) * ps)
    flat = _pad_rows(flat, tile)
    ids = _pad_rows(ids, tile, fill=-1)
    pad_pages = (flat.shape[0] - P * ps) // ps
    if pad_pages:
        page_mask = jnp.pad(page_mask, ((0, 0), (0, pad_pages)))
    return _ivf_kernel(queries, flat, ids, page_mask, k=k, page_size=ps,
                       tile=tile, interpret=_interpret(m))


def centroid_probe(centroids: jax.Array, queries: jax.Array, nprobe: int, *,
                   valid: Optional[jax.Array] = None, tile: int = 512,
                   mode: str = DEFAULT_MODE) -> Tuple[jax.Array, jax.Array]:
    """Coarse probe -> (scores [B,nprobe], cluster ids [B,nprobe])."""
    m = resolve_mode(mode)
    Nc = centroids.shape[0]
    if valid is None:
        valid = jnp.ones((Nc,), bool)
    if m == "ref":
        s = ref_mod.centroid_probe_ref(centroids, queries, valid)
    else:
        tile = min(tile, Nc)
        cent = _pad_rows(centroids, tile)
        v = _pad_rows(valid, tile, fill=False)
        s = _probe_kernel(queries, cent, v, tile=tile,
                          interpret=_interpret(m))[:, :Nc]
    return jax.lax.top_k(s, nprobe)


def probe_and_topk(queries: jax.Array, centroids: jax.Array,
                   pages: jax.Array, page_ids: jax.Array,
                   page_cluster: jax.Array, *, nprobe: int, k: int,
                   valid: Optional[jax.Array] = None, cent_tile: int = 512,
                   page_tile: int = 8, mode: str = DEFAULT_MODE,
                   ) -> Tuple[jax.Array, jax.Array]:
    """ONE-launch fused retrieval over resident pool pages: centroid
    probe + top-nprobe cluster admission + masked top-k, reading the
    pool's ``device_view`` (pages [P,ps,d], page_ids [P,ps],
    page_cluster [P]) in place.  Replaces the ``centroid_probe`` ->
    host-built page mask -> ``ivf_topk``-over-compacted-slab chain on
    the serving hot path.  Returns (scores [B,k], doc ids [B,k])."""
    m = resolve_mode(mode)
    Nc = centroids.shape[0]
    nprobe = max(1, min(nprobe, Nc))
    if valid is None:
        valid = jnp.ones((Nc,), bool)
    if m == "ref":
        return ref_mod.probe_and_topk_ref(queries, centroids, valid, pages,
                                          page_ids, page_cluster, nprobe, k)
    ct = min(cent_tile, Nc)
    cent = _pad_rows(centroids, ct)
    v = _pad_rows(valid, ct, fill=False)
    P = pages.shape[0]
    pt = _divisor_tile(P, page_tile)
    return _probe_topk_kernel(queries, cent, v, pages, page_ids,
                              page_cluster, nprobe=nprobe, k=k, cent_tile=ct,
                              page_tile=pt, interpret=_interpret(m))


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, pos: jax.Array, *,
                 window: int = 0, tile: int = 512,
                 mode: str = DEFAULT_MODE) -> jax.Array:
    """Decode attention [B,KVH,G,Dh] over dense KV [B,S,KVH,Dh]."""
    m = resolve_mode(mode)
    if m == "ref":
        return ref_mod.flash_decode_ref(q, k, v, pos, window)
    S = k.shape[1]
    tile = min(tile, S)
    if S % tile:
        tile = S
    return _flash_kernel(q, k, v, pos, window=window, tile=tile,
                         interpret=_interpret(m))


def flash_decode_paged(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       block_table: jax.Array, lengths: jax.Array, *,
                       window: int = 0,
                       mode: str = DEFAULT_MODE) -> jax.Array:
    """Decode attention [B,KVH,G,Dh] over paged KV [NP,ps,KVH,Dh]
    gathered through ``block_table`` [B,max_blocks] (-1 = unallocated)
    with per-request ``lengths`` [B] — the block-table form of
    ``flash_decode`` (identical numerics at ``pos = lengths - 1``)."""
    m = resolve_mode(mode)
    if m == "ref":
        return ref_mod.flash_decode_paged_ref(q, k_pages, v_pages,
                                              block_table, lengths, window)
    return _flash_paged_kernel(q, k_pages, v_pages, block_table, lengths,
                               window=window, interpret=_interpret(m))


def flash_decode_spliced(q: jax.Array, k_pages: jax.Array,
                         v_pages: jax.Array, block_table: jax.Array,
                         lengths: jax.Array, page_delta: jax.Array,
                         page_valid: jax.Array, *,
                         rope_fraction: float = 1.0,
                         rope_theta: float = 10_000.0,
                         mode: str = DEFAULT_MODE) -> jax.Array:
    """Paged decode attention over a block table mixing fresh pages with
    spliced chunk-KV pages: per-page reordered-RoPE reindexing
    (``page_delta`` [B,MB], the constant rotation offset per page) plus
    per-page live-token masking (``page_valid`` [B,MB], < ps only on a
    spliced chunk's partial last page).  A Pallas plane for the spliced
    form does not exist yet, so every resolved mode runs the jnp oracle
    — resolution still happens so invalid modes fail loudly and the
    ``REPRO_KERNEL_MODE`` switch stays uniform across entry points."""
    resolve_mode(mode)
    return ref_mod.flash_decode_spliced_ref(
        q, k_pages, v_pages, block_table, lengths, page_delta, page_valid,
        rope_fraction=rope_fraction, rope_theta=rope_theta)
