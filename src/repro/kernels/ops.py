"""Jit'd public wrappers around the Pallas kernels with oracle fallback.

Mode resolution:
  * "auto"            — real kernel on TPU, jnp oracle elsewhere (fast CPU)
  * "kernel"          — pallas kernel, compiled for the current backend
  * "kernel_interpret"— pallas kernel body interpreted in Python (CPU
                        validation path; what the parity tests use)
  * "ref"             — pure-jnp oracle
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_mod
from repro.kernels.centroid_probe import centroid_scores as _probe_kernel
from repro.kernels.flash_decode import flash_decode as _flash_kernel
from repro.kernels.ivf_topk import ivf_topk_flat as _ivf_kernel

DEFAULT_MODE = "auto"


def _resolve(mode: str) -> str:
    if mode == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "ref"
    return mode


def _pad_rows(x: jax.Array, multiple: int, fill=0):
    n = x.shape[0]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=fill)


def ivf_topk(pages: jax.Array, page_ids: jax.Array, page_mask: jax.Array,
             queries: jax.Array, k: int, *, tile: int = 1024,
             mode: str = DEFAULT_MODE) -> Tuple[jax.Array, jax.Array]:
    """Search the prefetch slab. pages [P,ps,d]; page_mask [P] or per-query
    [B,P]; queries [B,d] -> (scores [B,k], ids [B,k])."""
    m = _resolve(mode)
    if m == "ref":
        return ref_mod.ivf_topk_ref(pages, page_ids, page_mask, queries, k)
    B = queries.shape[0]
    P, ps, d = pages.shape
    flat = pages.reshape(P * ps, d)
    ids = page_ids.reshape(P * ps)
    if page_mask.ndim == 1:
        page_mask = jnp.broadcast_to(page_mask[None, :], (B, P))
    # tile must be a multiple of the page size and divide the padded slab
    tile = max(ps, (min(tile, P * ps) // ps) * ps)
    flat = _pad_rows(flat, tile)
    ids = _pad_rows(ids, tile, fill=-1)
    pad_pages = (flat.shape[0] - P * ps) // ps
    if pad_pages:
        page_mask = jnp.pad(page_mask, ((0, 0), (0, pad_pages)))
    return _ivf_kernel(queries, flat, ids, page_mask, k=k, page_size=ps,
                       tile=tile, interpret=(m == "kernel_interpret"))


def centroid_probe(centroids: jax.Array, queries: jax.Array, nprobe: int, *,
                   valid: Optional[jax.Array] = None, tile: int = 512,
                   mode: str = DEFAULT_MODE) -> Tuple[jax.Array, jax.Array]:
    """Coarse probe -> (scores [B,nprobe], cluster ids [B,nprobe])."""
    m = _resolve(mode)
    Nc = centroids.shape[0]
    if valid is None:
        valid = jnp.ones((Nc,), bool)
    if m == "ref":
        s = ref_mod.centroid_probe_ref(centroids, queries, valid)
    else:
        tile = min(tile, Nc)
        cent = _pad_rows(centroids, tile)
        v = _pad_rows(valid, tile, fill=False)
        s = _probe_kernel(queries, cent, v, tile=tile,
                          interpret=(m == "kernel_interpret"))[:, :Nc]
    return jax.lax.top_k(s, nprobe)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, pos: jax.Array, *,
                 window: int = 0, tile: int = 512,
                 mode: str = DEFAULT_MODE) -> jax.Array:
    """Decode attention [B,KVH,G,Dh] over KV [B,S,KVH,Dh]."""
    m = _resolve(mode)
    if m == "ref":
        return ref_mod.flash_decode_ref(q, k, v, pos, window)
    S = k.shape[1]
    tile = min(tile, S)
    if S % tile:
        tile = S
    return _flash_kernel(q, k, v, pos, window=window, tile=tile,
                         interpret=(m == "kernel_interpret"))
