"""Deterministic synthetic data pipeline with exact-resume semantics.

Token batches are a pure function of (seed, step), so resuming from a
checkpoint cursor reproduces the byte-identical stream — the property the
fault-tolerance tests assert. Sharding: the global batch is laid out
[global_batch, seq]; under pjit the batch dim shards over (pod, data).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.configs.base import ArchConfig


@dataclass
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    # markovian synthetic text: makes loss curves meaningful (learnable)
    order: int = 2


class TokenStream:
    def __init__(self, cfg: ArchConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data
        self.step = 0
        rng = np.random.default_rng(data.seed ^ 0xC0FFEE)
        v = cfg.vocab_size
        # sparse-ish transition structure for learnability
        self._trans = rng.integers(0, v, size=(min(v, 4096), 8))

    # -- exact resume ---------------------------------------------------------
    def cursor(self) -> Dict[str, int]:
        return {"step": self.step, "seed": self.data.seed}

    def restore(self, cursor: Dict[str, int]) -> None:
        assert cursor["seed"] == self.data.seed, "seed mismatch on resume"
        self.step = cursor["step"]

    # -- batches ---------------------------------------------------------------
    def _gen(self, step: int) -> Dict[str, np.ndarray]:
        d = self.data
        v = self.cfg.vocab_size
        rng = np.random.default_rng((d.seed << 20) ^ step)
        B, S = d.global_batch, d.seq_len
        nc = (self.cfg.frontend.num_codebooks
              if self.cfg.frontend and self.cfg.frontend.kind == "encodec_stub"
              else 0)
        shape = (B, S + 1, nc) if nc else (B, S + 1)
        toks = rng.integers(0, min(v, 4096), size=shape)
        # markov smoothing: next token drawn from cur's transition row
        pick = rng.integers(0, 8, size=shape)
        if nc:
            for c in range(nc):
                toks[:, 1:, c] = self._trans[toks[:, :-1, c] % len(self._trans),
                                             pick[:, 1:, c]]
        else:
            toks[:, 1:] = self._trans[toks[:, :-1] % len(self._trans),
                                      pick[:, 1:]]
        toks = toks.astype(np.int32) % v
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        fe = self.cfg.frontend
        if fe is not None and fe.kind == "vit_stub":
            batch["image_embeds"] = rng.standard_normal(
                (B, fe.num_prefix_embeddings, fe.embed_dim)).astype(np.float32)
        return batch

    def next_batch(self) -> Dict[str, np.ndarray]:
        b = self._gen(self.step)
        self.step += 1
        return b

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
