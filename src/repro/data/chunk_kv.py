"""Offline chunk-KV builder: precompute per-chunk KV pages once, reuse
them at serve time by block-table splice (TurboRAG, arXiv:2410.07590).

Every datastore chunk (document) is run through ``transformer.prefill``
**alone**, so its K is roped at chunk-local positions ``0..C-1`` —
position-independent at build time.  The resulting per-layer K/V is cut
into fixed-size pages (the serving slab's page geometry) and keyed by
doc id; at serve time ``ChunkKVCache`` lands pages H2D into the KV page
slab and ``KVCacheManager.splice_paged`` attaches them to a wave's
lease by block-table edit, with ``serve_step_paged_spliced`` applying
the per-page RoPE rotation offset (reordered RoPE — rotations compose,
so one constant rotation per page reindexes the chunk to its layout
position).

Chunk token streams are synthetic but deterministic — a pure function
of ``(seed, doc_id)`` like the training pipeline's batches — so the
store built offline and a miss's prefill fallback at serve time agree
byte-for-byte, and the parity suite can re-prefill the exact same
tokens as an oracle.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.configs.base import ArchConfig


def chunk_tokens(doc_id: int, vocab_size: int, *, seed: int = 0,
                 min_len: int = 8, max_len: int = 24) -> np.ndarray:
    """Deterministic ragged token stream for one chunk: a pure function
    of ``(seed, doc_id)`` (lengths deliberately ragged against any page
    size so partially-filled last pages are the common case)."""
    rng = np.random.default_rng(
        (np.uint64(seed) << np.uint64(20)) ^ np.uint64(doc_id * 2654435761))
    length = int(rng.integers(min_len, max_len + 1))
    return rng.integers(0, vocab_size, size=length).astype(np.int32)


@dataclass
class ChunkKV:
    """One chunk's precomputed KV: per-layer pages ``[L, n_pages,
    page_size, KVH, Dh]`` (chunk-local RoPE; the tail of the last page
    is zero padding masked at attention time), the live token count,
    and the IVF cluster the chunk belongs to (-1 = unmapped)."""

    k: np.ndarray
    v: np.ndarray
    length: int
    cluster: int = -1

    @property
    def num_pages(self) -> int:
        return self.k.shape[1]


@dataclass
class ChunkKVStore:
    """Host-side chunk-KV corpus: doc id -> precomputed pages, plus the
    page geometry they were cut to and the doc->cluster map lookahead
    prefetch walks (predicted clusters -> their docs' pages)."""

    page_size: int
    chunks: Dict[int, ChunkKV] = field(default_factory=dict)
    seed: int = 0

    def __contains__(self, doc_id: int) -> bool:
        return int(doc_id) in self.chunks

    def __len__(self) -> int:
        return len(self.chunks)

    def get(self, doc_id: int) -> Optional[ChunkKV]:
        return self.chunks.get(int(doc_id))

    def add(self, doc_id: int, chunk: ChunkKV) -> None:
        self.chunks[int(doc_id)] = chunk

    def num_pages(self, doc_id: int) -> int:
        c = self.chunks.get(int(doc_id))
        return 0 if c is None else c.num_pages

    def total_pages(self) -> int:
        return sum(c.num_pages for c in self.chunks.values())

    def docs_in_cluster(self, cluster: int) -> List[int]:
        return sorted(d for d, c in self.chunks.items()
                      if c.cluster == int(cluster))

    # -- persistence (the CLI's artifact format) ----------------------------
    def save(self, path: str) -> None:
        """One ``.npz``: per-doc k/v arrays plus a JSON meta record."""
        arrays: Dict[str, np.ndarray] = {}
        meta = {"page_size": self.page_size, "seed": self.seed, "docs": {}}
        for d, c in sorted(self.chunks.items()):
            arrays[f"k_{d}"] = c.k
            arrays[f"v_{d}"] = c.v
            meta["docs"][str(d)] = {"length": c.length, "cluster": c.cluster}
        arrays["meta"] = np.frombuffer(
            json.dumps(meta).encode(), dtype=np.uint8)
        np.savez_compressed(path, **arrays)

    @classmethod
    def load(cls, path: str) -> "ChunkKVStore":
        with np.load(path) as z:
            meta = json.loads(bytes(z["meta"]).decode())
            store = cls(page_size=int(meta["page_size"]),
                        seed=int(meta.get("seed", 0)))
            for d, m in meta["docs"].items():
                store.add(int(d), ChunkKV(k=z[f"k_{d}"], v=z[f"v_{d}"],
                                          length=int(m["length"]),
                                          cluster=int(m["cluster"])))
        return store


def pages_from_cache(cache_k: np.ndarray, cache_v: np.ndarray, length: int,
                     page_size: int) -> "tuple[np.ndarray, np.ndarray]":
    """Cut a dense single-sequence cache ``[L, S, KVH, Dh]`` into pages
    ``[L, n_pages, page_size, KVH, Dh]`` (zero-padded last page)."""
    L, S, KVH, Dh = cache_k.shape
    if length > S:
        raise ValueError(f"length {length} exceeds cache extent {S}")
    npg = -(-length // page_size)
    padded = npg * page_size
    out = []
    for a in (cache_k, cache_v):
        buf = np.zeros((L, padded, KVH, Dh), a.dtype)
        buf[:, :length] = a[:, :length]
        out.append(buf.reshape(L, npg, page_size, KVH, Dh))
    return out[0], out[1]


def build_chunk(params, cfg: ArchConfig, doc_id: int, *, page_size: int,
                seed: int = 0, min_len: int = 8, max_len: int = 24,
                cluster: int = -1, dtype=np.float32) -> ChunkKV:
    """Prefill ONE chunk at chunk-local positions and page its KV —
    also the serve-time miss fallback (``ChunkKVCache`` backfill)."""
    from repro.models import transformer as tf

    toks = chunk_tokens(doc_id, cfg.vocab_size, seed=seed,
                        min_len=min_len, max_len=max_len)
    _, cache = tf.prefill(params, {"tokens": np.asarray(toks)[None]}, cfg)
    k = np.asarray(cache["k"][:, 0], dtype)       # [L, S, KVH, Dh]
    v = np.asarray(cache["v"][:, 0], dtype)
    kp, vp = pages_from_cache(k, v, len(toks), page_size)
    return ChunkKV(k=kp, v=vp, length=len(toks), cluster=int(cluster))


def build_chunk_kv(params, cfg: ArchConfig, doc_ids: Iterable[int], *,
                   page_size: int, seed: int = 0, min_len: int = 8,
                   max_len: int = 24,
                   cluster_of: Optional[Callable[[int], int]] = None,
                   dtype=np.float32) -> ChunkKVStore:
    """The offline builder: one prefill per chunk, paged and keyed by
    doc id.  ``cluster_of`` maps a doc to its IVF cluster (how
    lookahead's predicted clusters resolve to prefetchable chunk
    pages); None leaves chunks unmapped."""
    store = ChunkKVStore(page_size=page_size, seed=seed)
    for d in doc_ids:
        d = int(d)
        store.add(d, build_chunk(
            params, cfg, d, page_size=page_size, seed=seed, min_len=min_len,
            max_len=max_len,
            cluster=-1 if cluster_of is None else int(cluster_of(d)),
            dtype=dtype))
    return store


def cluster_map_from_assignments(assignments: Sequence[int],
                                 ) -> Callable[[int], int]:
    """``cluster_of`` from an IVF assignment vector (doc id -> cluster),
    -1 for out-of-range ids."""
    arr = np.asarray(assignments)

    def cluster_of(doc_id: int) -> int:
        return int(arr[doc_id]) if 0 <= doc_id < len(arr) else -1

    return cluster_of
