from repro.data.chunk_kv import (ChunkKV, ChunkKVStore, build_chunk,
                                 build_chunk_kv, chunk_tokens,
                                 cluster_map_from_assignments,
                                 pages_from_cache)
from repro.data.pipeline import DataConfig, TokenStream

__all__ = [
    "ChunkKV", "ChunkKVStore", "DataConfig", "TokenStream", "build_chunk",
    "build_chunk_kv", "chunk_tokens", "cluster_map_from_assignments",
    "pages_from_cache",
]
