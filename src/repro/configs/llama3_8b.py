"""Llama-3-8B [arXiv:2407.21783] — the paper's own evaluation model.

TeleRAG's single-GPU latency and H100 throughput experiments use
Llama-3.2-3B / Llama-3-8B / Mistral-22B; we carry the 8B as the
paper-faithful reference generator for the RAG benchmarks.
"""

from repro.configs.base import ArchConfig, register

LLAMA3_8B = register(ArchConfig(
    name="llama3-8b",
    family="dense",
    source="arXiv:2407.21783; hf (paper's evaluation model)",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=128_256,
    attn_kind="gqa",
    rope_theta=500_000.0,
    mlp_act="silu",
    mlp_gated=True,
    subquadratic=False,
))
