"""Architecture configuration system.

Every assigned architecture is a frozen ``ArchConfig`` registered under its
public id (``--arch <id>``). Configs are *data only* — the generic model
assembler in ``repro.models.transformer`` interprets them. ``reduced()``
produces the small-family config used by per-arch smoke tests; full-size
configs are only ever lowered via ShapeDtypeStructs (no allocation).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    # Arctic-style dense residual MLP running in parallel with the experts.
    dense_residual_d_ff: Optional[int] = None
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # load-balancing auxiliary loss weight (Switch/GShard style)
    aux_loss_weight: float = 0.01
    # dispatch subgroup size: bounds capacity C = ceil(Tg*K*cf/E) so the
    # [G,Tg,E,C] dispatch tensor stays O(T_total * E * C_g) (see moe.py)
    group_size: int = 512


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2 / MiniCPM3)."""

    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class SSMConfig:
    """Covers both RWKV6 time-mix and Mamba2 SSD parameterizations."""

    kind: str  # "rwkv6" | "mamba2"
    state_dim: int = 64        # N: per-head state size (mamba2) / head dim (rwkv6)
    head_dim: int = 64         # P: channels per head
    conv_width: int = 4        # mamba2 short conv
    expand: int = 2            # mamba2 inner expansion
    chunk_size: int = 128      # chunked-scan block length (train/prefill)


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend stub: input_specs() supplies precomputed embeddings."""

    kind: str                  # "vit_stub" | "encodec_stub"
    num_prefix_embeddings: int = 0   # vlm: patch embeddings prepended
    embed_dim: int = 0               # incoming embedding width (projected to d_model)
    num_codebooks: int = 1           # audio: parallel EnCodec codebooks


# ---------------------------------------------------------------------------
# ArchConfig
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | vlm | audio
    source: str                # provenance string from the assignment

    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: Optional[int] = None   # default: d_model // num_heads

    # attention flavour ------------------------------------------------------
    attn_kind: str = "gqa"     # gqa | mla | none
    sliding_window: Optional[int] = None
    local_global_pattern: bool = False   # gemma2: alternate local/global
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0           # nemotron: partial rotary

    # mlp --------------------------------------------------------------------
    mlp_act: str = "silu"      # silu | gelu | relu2
    mlp_gated: bool = True     # SwiGLU/GeGLU vs plain 2-matmul MLP

    # family extensions ------------------------------------------------------
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    frontend: Optional[FrontendConfig] = None
    # hybrid (zamba2): shared attention block applied every `shared_attn_every`
    # backbone blocks, with per-application LoRA deltas of this rank.
    shared_attn_every: int = 0
    shared_attn_lora_rank: int = 0

    # misc -------------------------------------------------------------------
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # long_500k eligibility (sub-quadratic attention); see DESIGN.md §4.
    subquadratic: bool = False

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def has_attention(self) -> bool:
        return self.attn_kind != "none"

    def param_count(self) -> int:
        """Analytic parameter count (exact for our parameterization)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim
        n = V * d  # embedding
        if not self.tie_embeddings:
            n += V * d  # unembedding
        per_layer = 0
        if self.attn_kind == "gqa":
            per_layer += d * self.num_heads * hd          # Wq
            per_layer += 2 * d * self.num_kv_heads * hd   # Wk, Wv
            per_layer += self.num_heads * hd * d          # Wo
        elif self.attn_kind == "mla":
            m = self.mla
            qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
            per_layer += d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk_dim
            per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            per_layer += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            per_layer += self.num_heads * m.v_head_dim * d
        if self.ssm is not None:
            s = self.ssm
            if s.kind == "rwkv6":
                # time-mix: r,k,v,g,o projections + decay/bonus params + channel-mix
                per_layer += 5 * d * d + 2 * d + self.d_ff * d * 2
            else:  # mamba2 (single-group B/C, standard ngroups=1)
                d_in = s.expand * d
                n_heads = d_in // s.head_dim
                per_layer += d * (2 * d_in + 2 * s.state_dim + n_heads)
                per_layer += d_in * d  # out proj
        if self.moe is not None:
            mo = self.moe
            per_layer += d * mo.num_experts                      # router
            per_layer += mo.num_experts * 3 * d * mo.d_ff_expert  # gated experts
            if mo.dense_residual_d_ff:
                per_layer += 3 * d * mo.dense_residual_d_ff
        elif self.d_ff and self.ssm is None or (self.ssm is not None and self.ssm.kind == "mamba2" and self.d_ff):
            pass
        # Per-layer MLP: dense/moe-attn layers only. rwkv6 counts its
        # channel-mix in its own branch; mamba2/hybrid blocks carry no MLP
        # (zamba2's MLP lives in the one shared attention block).
        if self.moe is None and self.d_ff and self.ssm is None:
            nmat = 3 if self.mlp_gated else 2
            per_layer += nmat * d * self.d_ff
        per_layer += 2 * d  # norms
        n += L * per_layer
        if self.shared_attn_every:
            n += 4 * d * d  # one shared attention block
            nmat = 3 if self.mlp_gated else 2
            n += nmat * d * self.d_ff  # shared block's MLP (counted once)
            n_apps = self.num_layers // self.shared_attn_every
            n += n_apps * self.shared_attn_lora_rank * 2 * d * 4
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        mo = self.moe
        inactive = (mo.num_experts - mo.top_k) * 3 * self.d_model * mo.d_ff_expert
        return full - self.num_layers * inactive

    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        kw: dict = dict(
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 4),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads else 0,
            d_ff=256,
            vocab_size=512,
            head_dim=32 if self.head_dim is not None or self.attn_kind == "gqa" else None,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=min(self.moe.top_k, 2), d_ff_expert=64,
                dense_residual_d_ff=64 if self.moe.dense_residual_d_ff else None)
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                  qk_nope_head_dim=16, qk_rope_head_dim=16, v_head_dim=16)
            kw["head_dim"] = None
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, state_dim=16, head_dim=16, chunk_size=16)
        if self.frontend is not None:
            kw["frontend"] = dataclasses.replace(
                self.frontend,
                num_prefix_embeddings=min(self.frontend.num_prefix_embeddings, 8) or 0,
                embed_dim=min(self.frontend.embed_dim, 64) if self.frontend.embed_dim else 0)
        if self.shared_attn_every:
            kw["shared_attn_every"] = 2
            kw["shared_attn_lora_rank"] = 8
            kw["num_layers"] = 4
        if self.sliding_window:
            kw["sliding_window"] = 8
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch id {cfg.name!r}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    _ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_REGISTRY)}") from None


def list_archs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # import all arch modules for registration side effects
    from repro.configs import (  # noqa: F401
        gemma2_27b, minicpm3_4b, granite_20b, nemotron4_15b, granite_moe_3b,
        arctic_480b, rwkv6_3b, zamba2_2_7b, internvl2_1b, musicgen_large,
        llama3_8b,
    )
