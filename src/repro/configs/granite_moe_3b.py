"""Granite-MoE-3B-a800m [hf:ibm-granite; hf] — 40 experts top-8, tiny d_ff."""

from repro.configs.base import ArchConfig, MoEConfig, register

GRANITE_MOE_3B = register(ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,                 # per-expert FFN width
    vocab_size=49_155,
    attn_kind="gqa",
    moe=MoEConfig(
        num_experts=40,
        top_k=8,
        d_ff_expert=512,
    ),
    mlp_act="silu",
    mlp_gated=True,
    subquadratic=False,
))
