from repro.configs.base import (
    ArchConfig, MoEConfig, MLAConfig, SSMConfig, FrontendConfig,
    get_arch, list_archs, register,
)
from repro.configs.shapes import (
    ShapeSuite, SHAPE_SUITES, get_shape,
    TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
)

__all__ = [
    "ArchConfig", "MoEConfig", "MLAConfig", "SSMConfig", "FrontendConfig",
    "get_arch", "list_archs", "register",
    "ShapeSuite", "SHAPE_SUITES", "get_shape",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
]
