"""Nemotron-4-15B [arXiv:2402.16819; unverified] — GQA, squared-ReLU MLP."""

from repro.configs.base import ArchConfig, register

NEMOTRON4_15B = register(ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    source="arXiv:2402.16819; unverified",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24_576,
    vocab_size=256_000,
    attn_kind="gqa",
    mlp_act="relu2",          # squared ReLU
    mlp_gated=False,          # plain up/down MLP
    rope_fraction=0.5,        # partial rotary embedding
    subquadratic=False,
))
