"""InternVL2-1B [arXiv:2404.16821; hf] — InternViT frontend (stub) + InternLM2 backbone."""

from repro.configs.base import ArchConfig, FrontendConfig, register

INTERNVL2_1B = register(ArchConfig(
    name="internvl2-1b",
    family="vlm",
    source="arXiv:2404.16821; hf",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151_655,
    attn_kind="gqa",
    rope_theta=1_000_000.0,
    frontend=FrontendConfig(
        kind="vit_stub",
        num_prefix_embeddings=256,   # InternViT patch embeddings after pixel-unshuffle
        embed_dim=1024,              # InternViT-300M hidden width, projected to d_model
    ),
    mlp_act="silu",
    mlp_gated=True,
    subquadratic=False,
))
