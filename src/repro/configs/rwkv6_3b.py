"""RWKV6-3B "Finch" [arXiv:2404.05892; hf] — attention-free, data-dependent decay."""

from repro.configs.base import ArchConfig, SSMConfig, register

RWKV6_3B = register(ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    source="arXiv:2404.05892; hf",
    num_layers=32,
    d_model=2560,
    num_heads=0,              # attention-free
    num_kv_heads=0,
    d_ff=8960,                # channel-mix width
    vocab_size=65_536,
    attn_kind="none",
    ssm=SSMConfig(
        kind="rwkv6",
        head_dim=64,          # 40 time-mix heads of 64 channels
        state_dim=64,
        chunk_size=128,
    ),
    mlp_act="relu2",          # rwkv channel-mix uses squared relu
    mlp_gated=False,
    subquadratic=True,        # O(1) decode state, linear train/prefill
))
