"""Zamba2-2.7B [arXiv:2411.15242; hf] — Mamba2 backbone + shared attention blocks."""

from repro.configs.base import ArchConfig, SSMConfig, register

ZAMBA2_2_7B = register(ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    source="arXiv:2411.15242; hf",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10_240,
    vocab_size=32_000,
    attn_kind="gqa",          # flavour of the *shared* attention block
    ssm=SSMConfig(
        kind="mamba2",
        state_dim=64,
        head_dim=64,
        conv_width=4,
        expand=2,
        chunk_size=128,
    ),
    # one shared attention(+MLP) block applied every 6 mamba blocks, with
    # per-application LoRA deltas (Zamba2's parameter-sharing design).
    shared_attn_every=6,
    shared_attn_lora_rank=128,
    mlp_act="gelu",
    mlp_gated=True,
    subquadratic=True,        # mamba state + periodic attention
))
