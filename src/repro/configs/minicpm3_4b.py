"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B; hf] — dense with MLA attention."""

from repro.configs.base import ArchConfig, MLAConfig, register

MINICPM3_4B = register(ArchConfig(
    name="minicpm3-4b",
    family="dense",
    source="hf:openbmb/MiniCPM3-4B; hf",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73_448,
    attn_kind="mla",
    mla=MLAConfig(
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    mlp_act="silu",
    mlp_gated=True,
    subquadratic=False,  # full attention (compressed KV, still O(S) per step)
))
