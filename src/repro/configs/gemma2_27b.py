"""Gemma2-27B [arXiv:2408.00118; hf] — dense, local/global alternating, softcaps."""

from repro.configs.base import ArchConfig, register

GEMMA2_27B = register(ArchConfig(
    name="gemma2-27b",
    family="dense",
    source="arXiv:2408.00118; hf",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36_864,
    vocab_size=256_000,
    attn_kind="gqa",
    sliding_window=4_096,
    local_global_pattern=True,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    mlp_act="gelu",
    mlp_gated=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
    # half the layers are 4096-token sliding window; global-layer KV is
    # sequence-sharded for long_500k (DESIGN.md §4).
    subquadratic=True,
))
