"""Input-shape suites assigned to every architecture.

Each cell of the (arch × shape) matrix lowers a specific entry point:
  train_4k    -> train_step      (seq 4096, global batch 256)
  prefill_32k -> prefill         (seq 32768, global batch 32)
  decode_32k  -> serve_step      (1 new token, KV len 32768, batch 128)
  long_500k   -> serve_step      (1 new token, KV len 524288, batch 1;
                                  sub-quadratic archs only)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class ShapeSuite:
    name: str
    entry: str          # "train_step" | "prefill" | "serve_step"
    seq_len: int
    global_batch: int

    def skip_reason(self, cfg: ArchConfig) -> Optional[str]:
        if self.name == "long_500k" and not cfg.subquadratic:
            return "skip:full-attn (long_500k requires sub-quadratic attention)"
        return None


TRAIN_4K = ShapeSuite("train_4k", "train_step", 4_096, 256)
PREFILL_32K = ShapeSuite("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeSuite("decode_32k", "serve_step", 32_768, 128)
LONG_500K = ShapeSuite("long_500k", "serve_step", 524_288, 1)

SHAPE_SUITES: Tuple[ShapeSuite, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def get_shape(name: str) -> ShapeSuite:
    for s in SHAPE_SUITES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape suite {name!r}; available: "
                   f"{[s.name for s in SHAPE_SUITES]}")
