"""Granite-20B-code [arXiv:2405.04324; hf] — llama-arch with MQA (kv=1)."""

from repro.configs.base import ArchConfig, register

GRANITE_20B = register(ArchConfig(
    name="granite-20b",
    family="dense",
    source="arXiv:2405.04324; hf",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,           # multi-query attention
    head_dim=128,
    d_ff=24_576,
    vocab_size=49_152,
    attn_kind="gqa",
    # GPT-BigCode lineage: plain (non-gated) GELU MLP; llama-style rotary
    # attention with multi-query KV. Non-gated matches the 20B name.
    mlp_act="gelu",
    mlp_gated=False,
    subquadratic=False,
))
