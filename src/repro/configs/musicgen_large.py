"""MusicGen-Large [arXiv:2306.05284; hf] — decoder-only over EnCodec tokens.

The modality frontend (EnCodec) is a stub: ``input_specs()`` supplies token
ids for 4 parallel codebooks (vocab 2048 each). Codebook embeddings are
summed on the way in; the model emits 4 parallel heads on the way out. The
codebook delay pattern is handled in the trace layer, not the backbone.
"""

from repro.configs.base import ArchConfig, FrontendConfig, register

MUSICGEN_LARGE = register(ArchConfig(
    name="musicgen-large",
    family="audio",
    source="arXiv:2306.05284; hf",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,              # per-codebook vocabulary
    attn_kind="gqa",
    frontend=FrontendConfig(
        kind="encodec_stub",
        num_codebooks=4,
    ),
    mlp_act="gelu",
    mlp_gated=False,
    subquadratic=False,
))
