"""Snowflake Arctic-480B [hf:Snowflake/snowflake-arctic-base; hf].

128 experts top-2 with a *dense residual* MLP in parallel (Arctic's
dense-MoE hybrid design).
"""

from repro.configs.base import ArchConfig, MoEConfig, register

ARCTIC_480B = register(ArchConfig(
    name="arctic-480b",
    family="moe",
    source="hf:Snowflake/snowflake-arctic-base; hf",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,                # per-expert FFN width
    vocab_size=32_000,
    attn_kind="gqa",
    moe=MoEConfig(
        num_experts=128,
        top_k=2,
        d_ff_expert=4864,
        dense_residual_d_ff=4864,
    ),
    mlp_act="silu",
    mlp_gated=True,
    subquadratic=False,
))
