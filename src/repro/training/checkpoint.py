"""Fault-tolerant checkpointing: atomic, step-scoped, resumable.

Layout:
  <dir>/step_000123.tmp/...   (written)
  <dir>/step_000123/          (atomic rename commit)
  <dir>/LATEST                (text file naming the newest committed step)

Each checkpoint stores: flattened param/opt leaves as .npy, the pytree
structure, the data-iterator cursor, and optional engine snapshots
(serving cache state). Restore picks LATEST (or an explicit step),
tolerating a crash mid-write: uncommitted ``.tmp`` dirs are ignored and
garbage-collected. On multi-host deployments each host writes its own
process directory; here process count is 1 (documented in DESIGN.md §5).
"""

from __future__ import annotations

import json
import os
import shutil
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(directory: str, step: int, state: Dict[str, Any], *,
                    keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest: Dict[str, Any] = {"step": step, "trees": {}}
    for tree_name, tree in state.items():
        if tree is None:
            continue
        if isinstance(tree, (int, float, str, dict)) and not _has_arrays(tree):
            manifest["trees"][tree_name] = {"kind": "json", "value": tree}
            continue
        leaves = _leaf_paths(tree)
        treedef = jax.tree.structure(tree)
        entry = {"kind": "arrays", "treedef": str(treedef), "leaves": []}
        for i, (key, leaf) in enumerate(leaves):
            fn = f"{tree_name}__{i:05d}.npy"
            arr = np.asarray(leaf)
            orig = str(arr.dtype)
            if arr.dtype.kind == "V" or "bfloat16" in orig:
                # numpy can't round-trip ml_dtypes: store widened fp32
                arr = np.asarray(jax.numpy.asarray(leaf,
                                                   jax.numpy.float32))
                orig = "bfloat16"
            np.save(os.path.join(tmp, fn), arr)
            entry["leaves"].append({"key": key, "file": fn, "dtype": orig})
        manifest["trees"][tree_name] = entry
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    os.rename(tmp, final)                        # atomic commit
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(name)
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    _gc(directory, keep)
    return final


def _has_arrays(obj: Any) -> bool:
    return any(hasattr(l, "shape") for l in jax.tree.leaves(obj))


def _gc(directory: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
    # drop crashed partial writes
    for d in os.listdir(directory):
        if d.endswith(".tmp") and d.startswith("step_"):
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    latest = os.path.join(directory, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(directory, name)):
        return None
    return int(name.split("_")[1])


def restore_checkpoint(directory: str, template: Dict[str, Any],
                       step: Optional[int] = None) -> Tuple[int, Dict[str, Any]]:
    """Restore into the structure of ``template`` (tree-matched by order)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    out: Dict[str, Any] = {}
    for tree_name, tmpl in template.items():
        entry = manifest["trees"].get(tree_name)
        if entry is None:
            out[tree_name] = tmpl
            continue
        if entry["kind"] == "json":
            out[tree_name] = entry["value"]
            continue
        leaves = [np.load(os.path.join(path, l["file"]))
                  for l in entry["leaves"]]
        treedef = jax.tree.structure(tmpl)
        tmpl_leaves = jax.tree.leaves(tmpl)
        assert len(leaves) == len(tmpl_leaves), (
            tree_name, len(leaves), len(tmpl_leaves))
        cast = [np.asarray(l).astype(t.dtype) if hasattr(t, "dtype") else l
                for l, t in zip(leaves, tmpl_leaves)]
        out[tree_name] = jax.tree.unflatten(treedef, cast)
    return manifest["step"], out
