from repro.training.checkpoint import (latest_step, restore_checkpoint,
                                       save_checkpoint)
from repro.training.optimizer import (OptConfig, adamw_update, global_norm,
                                      init_opt_state, schedule)
from repro.training.train_loop import (init_training, make_loss_fn,
                                       make_manual_dp_train_step,
                                       make_train_step)

__all__ = [
    "latest_step", "restore_checkpoint", "save_checkpoint",
    "OptConfig", "adamw_update", "global_norm", "init_opt_state", "schedule",
    "init_training", "make_loss_fn", "make_manual_dp_train_step",
    "make_train_step",
]
