"""AdamW with configurable moment dtype + cosine schedule (pure JAX).

``moment_dtype="bfloat16"`` halves optimizer-state HBM — required to fit
arctic-480b on v5e (DESIGN.md §4): params bf16 3.75 GB/chip + fp32 m,v
would be 15 GB/chip (over budget); bf16 m,v is 7.5 GB/chip.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"     # "float32" | "bfloat16"


def schedule(step: jax.Array, cfg: OptConfig) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params: Any, cfg: OptConfig) -> Dict[str, Any]:
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params: Any, grads: Any, state: Dict[str, Any],
                 cfg: OptConfig) -> Tuple[Any, Dict[str, Any], Dict[str, Any]]:
    step = state["step"] + 1
    lr = schedule(step, cfg)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd_one(p, g, m, v):
        g32 = g.astype(jnp.float32) * clip
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    upd = upd_one

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    outs = [upd(p, g, m, v) for p, g, m, v in
            zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in outs])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
