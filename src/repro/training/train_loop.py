"""Training step factories: SPMD (pjit-implicit collectives) and
explicit-collective DP (shard_map) with optional int8 gradient compression.

The SPMD path is what the dry-run lowers (GSPMD inserts the grad
all-reduces from the shardings). The manual path exists because gradient
compression must own its psum to actually shrink wire bytes.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed import compression as comp
from repro.models import transformer as tf
from repro.training.optimizer import OptConfig, adamw_update, init_opt_state


def make_loss_fn(cfg: ArchConfig, *, attn_chunk: int = 1024,
                 remat: bool = True, remat_group: int = 4, act_spec=None,
                 loss_chunk: int = 512) -> Callable:
    def loss_fn(params, batch):
        return tf.loss_fn(params, batch, cfg, attn_chunk=attn_chunk,
                          remat=remat, remat_group=remat_group,
                          act_spec=act_spec, loss_chunk=loss_chunk)
    return loss_fn


def make_train_step(cfg: ArchConfig, opt_cfg: OptConfig, *,
                    attn_chunk: int = 1024, remat: bool = True,
                    remat_group: int = 4, act_spec=None,
                    loss_chunk: int = 512, accum_steps: int = 1) -> Callable:
    """SPMD train step: (params, opt_state, batch) -> (params, opt_state,
    metrics). Shard via pjit in/out shardings; collectives are implicit.

    accum_steps > 1 splits the global batch into microbatches scanned with
    gradient accumulation: transient activation memory scales 1/accum at
    the cost of re-gathering FSDP weights per microbatch.
    """
    loss_fn = make_loss_fn(cfg, attn_chunk=attn_chunk, remat=remat,
                           remat_group=remat_group, act_spec=act_spec,
                           loss_chunk=loss_chunk)

    def train_step(params, opt_state, batch):
        if accum_steps <= 1:
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape((accum_steps, a.shape[0] // accum_steps)
                                    + a.shape[1:]), batch)

            def mb(carry, mbatch):
                gacc, lsum, auxsum = carry
                (l, a), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mbatch)
                gacc = jax.tree.map(jnp.add, gacc, g)
                auxsum = jax.tree.map(jnp.add, auxsum, a)
                return (gacc, lsum + l, auxsum), None

            g0 = jax.tree.map(jnp.zeros_like, params)
            aux0 = {"ce": jnp.zeros(()), "aux": jnp.zeros(()),
                    "tokens": jnp.zeros(())}
            (grads, loss, aux), _ = jax.lax.scan(
                mb, (g0, jnp.zeros(()), aux0), micro)
            inv = 1.0 / accum_steps
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss = loss * inv
            aux = {"ce": aux["ce"] * inv, "aux": aux["aux"] * inv,
                   "tokens": aux["tokens"]}
        params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **aux, **om}
        return params, opt_state, metrics

    return train_step


def make_manual_dp_train_step(cfg: ArchConfig, opt_cfg: OptConfig,
                              mesh: Mesh, *, compress: bool = False,
                              axis: str = "data", attn_chunk: int = 1024,
                              remat: bool = True) -> Callable:
    """Pure-DP train step with explicit psum (compressible).

    Params replicated; batch sharded over ``axis``. State carries the
    error-feedback tree when compression is on.
    """
    loss_fn = make_loss_fn(cfg, attn_chunk=attn_chunk, remat=remat)

    def step(params, opt_state, err, batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        if compress:
            grads, err = comp.compressed_psum(grads, err, axis)
        else:
            grads = jax.lax.pmean(grads, axis)
        loss = jax.lax.pmean(loss, axis)
        params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, err, {"loss": loss, **om}

    from repro.compat import shard_map
    shard_step = shard_map(
        step, mesh=mesh,
        in_specs=(P(), P(), P(), P(axis)),
        out_specs=(P(), P(), P(), P()),
        check_vma=False)
    return jax.jit(shard_step)


def init_training(cfg: ArchConfig, opt_cfg: OptConfig, key: jax.Array,
                  ) -> Tuple[Any, Any]:
    params = tf.init_params(cfg, key)
    return params, init_opt_state(params, opt_cfg)
