"""telint static rules: AST lint for the lease/clock/kernel discipline
the serving stack depends on (docs/ANALYSIS.md has the full catalog).

Rules (each is a heuristic tuned to THIS codebase's idioms, not a
general-purpose linter — violations it cannot prove are skipped, and
pre-existing findings are grandfathered via ``analysis/baseline.json``):

  TL001  lease leak — the result of an acquire-like call
         (``lease_slots`` / ``lease_bytes`` / ``reserve`` / ``admit`` /
         ``acquire`` / ``acquire_paged`` / ``pin_clusters``) is bound to
         a local that neither escapes the function (returned, yielded,
         stored on an owner object/container) nor is released under a
         ``try/finally`` or ``except`` cleanup path.
  TL002  wall-clock discipline — ``time.time`` / ``perf_counter`` /
         ``monotonic`` / ``process_time`` inside the deterministic core
         (serving/memory/core/obs/analysis); the event clock (and the
         injectable ``repro.obs.clock`` sources) are the only
         sanctioned time reads there.
  TL003  kernel-mode discipline — ``interpret=`` kwargs or
         interpret-mode string literals passed at call sites outside
         ``src/repro/kernels/`` (mode resolution belongs to
         ``kernels/ops.py::resolve_mode``).
  TL004  tenant threading — lease/ticket/ledger calls that accept a
         ``tenant=`` kwarg but are called without one inside
         serving/memory, silently falling back to the untenanted
         sentinel.
  TL005  swallowed pressure — bare ``except:`` anywhere, or an
         ``except`` catching ``PoolExhausted`` / ``Exception`` /
         ``BaseException`` whose whole body is ``pass``/``...``.

This module is **stdlib-only** (ast + dataclasses + json): the CI
ratchet step runs it without installing jax/numpy.
"""

from __future__ import annotations

import ast
import json
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# -- rule vocabulary ---------------------------------------------------------

# method names whose return value is a lease/ticket/reservation that
# must be released (TL001) — receiver-agnostic: the repo's pool, buffer,
# admission controller and KV manager all use these names
ACQUIRE_METHODS = frozenset({
    "lease_slots", "lease_bytes", "reserve", "admit",
    "acquire", "acquire_paged", "pin_clusters",
})

# method names that release/cancel/transfer what an acquire returned
RELEASE_METHODS = frozenset({
    "release", "release_paged", "release_pins", "unpin",
    "cancel", "commit", "drop", "drop_all", "evict_clusters",
})

WALL_CLOCK_ATTRS = frozenset({
    "time", "perf_counter", "monotonic", "process_time",
    "perf_counter_ns", "monotonic_ns", "time_ns",
})

# packages forming the deterministic core: all timing there must flow
# through the event clock (TL002 scope)
CLOCKED_PACKAGES = ("serving/", "memory/", "core/", "obs/", "analysis/")

# the one sanctioned wall-time source (``repro.obs.clock``) plus launch
# drivers, which measure REAL decode/train wall time by design
WALL_CLOCK_ALLOWLIST = ("obs/clock.py",)

INTERPRET_MODE_LITERALS = frozenset({"interpret", "kernel_interpret"})

# methods that accept ``tenant=`` and mis-attribute to the untenanted
# sentinel when it is dropped (TL004) — scope: serving/ + memory/
TENANT_METHODS = frozenset({
    "lease_slots", "lease_bytes", "reserve", "admit",
    "acquire", "acquire_paged",
})
TENANT_PACKAGES = ("serving/", "memory/")


@dataclass(frozen=True)
class LintViolation:
    """One finding: ``key`` (rule/path/symbol/detail) is what the
    ratchet baseline matches on — stable across line-number drift."""

    rule: str
    path: str          # repo-relative posix path
    line: int
    symbol: str        # enclosing function qualname ("" = module level)
    detail: str        # what triggered (name/attr), part of the key
    message: str

    @property
    def key(self) -> str:
        return f"{self.rule}:{self.path}:{self.symbol}:{self.detail}"

    def render(self) -> str:
        sym = f" [{self.symbol}]" if self.symbol else ""
        return f"{self.path}:{self.line}: {self.rule}{sym} {self.message}"


def _call_method_name(call: ast.Call) -> Optional[str]:
    """``obj.meth(...)`` -> ``meth``; plain ``meth(...)`` -> ``meth``."""
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


def _names_in(node: ast.AST) -> Iterable[str]:
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            yield n.id


def _call_arg_names(call: ast.Call) -> set:
    """Names appearing in a call's arguments (NOT its receiver — a
    shared receiver like ``pool`` must not key the registry excuse)."""
    out: set = set()
    for arg in list(call.args) + [k.value for k in call.keywords]:
        out.update(_names_in(arg))
    return out


# -- TL001: lease leak -------------------------------------------------------


class _FuncLeaseAudit:
    """Per-function escape/release analysis for acquire-bound locals."""

    def __init__(self, func: ast.AST, path: str, symbol: str):
        self.func = func
        self.path = path
        self.symbol = symbol
        # name -> (line, acquire method) for locals bound to an acquire
        self.acquired: Dict[str, Tuple[int, str]] = {}
        # names that escape the function (returned / yielded / stored on
        # an owner object or container — ownership transferred)
        self.escaped: set = set()
        # names released under a protected path (finally/except body)
        self.protected: set = set()
        # names appearing anywhere in a release-method call
        self.released: set = set()
        # loop-target aliases: ``for m, pins in zip(keys, hit_pins)``
        # makes a release of ``pins`` credit ``hit_pins`` too
        self.alias: Dict[str, set] = {}
        # argument names of each acquire call, per bound local — the
        # keyed-registry idiom: ``buffer.pin_clusters(m, cs)`` registers
        # the lease under key ``m`` and a *protected* ``buffer.unpin(m)``
        # drops it by key, so the lease object itself need not be named
        self.acquire_args: Dict[str, set] = {}
        # argument names of release calls on protected paths (keys)
        self.protected_args: set = set()
        # acquire calls whose result is discarded outright
        self.discarded: List[Tuple[int, str, set]] = []
        # target -> names its value was built from: ``res = R(lease=l)``
        # transfers ownership of ``l`` wherever ``res`` escapes to
        self.built_from: Dict[str, set] = {}

    def run(self) -> List[LintViolation]:
        body = getattr(self.func, "body", [])
        for stmt in body:
            self._scan_stmt(stmt, protected=False)
        # transitive escape: a name wrapped into an escaping object
        # (constructor arg, tuple member) escaped with it
        todo = list(self.escaped)
        while todo:
            for src in self.built_from.get(todo.pop(), ()):
                if src not in self.escaped:
                    self.escaped.add(src)
                    todo.append(src)
        out = [LintViolation(
            rule="TL001", path=self.path, line=line, symbol=self.symbol,
            detail=f"discard:{meth}",
            message=f"result of `.{meth}(...)` is discarded — the lease "
                    f"cannot be released on failure paths")
            for line, meth, args in self.discarded
            if not (args & self.protected_args)]
        for name, (line, meth) in self.acquired.items():
            if name in self.escaped or name in self.protected:
                continue
            if self.acquire_args.get(name, set()) & self.protected_args:
                # keyed-registry idiom: a protected release drops the
                # lease by the key it was acquired under
                continue
            if name in self.released:
                msg = (f"`{name}` from `.{meth}(...)` is released, but "
                       f"not on exception paths (no try/finally or "
                       f"except cleanup)")
            else:
                msg = (f"`{name}` from `.{meth}(...)` is never released "
                       f"and does not escape this function")
            out.append(LintViolation(
                rule="TL001", path=self.path, line=line,
                symbol=self.symbol, detail=name, message=msg))
        return out

    # -- statement walk ------------------------------------------------------
    def _scan_stmt(self, stmt: ast.stmt, *, protected: bool) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return                      # nested defs audited separately
        if isinstance(stmt, ast.Assign):
            self._scan_assign(stmt.targets, stmt.value)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self._scan_assign([stmt.target], stmt.value)
        elif isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
            m = _call_method_name(stmt.value)
            if m in ACQUIRE_METHODS:
                self.discarded.append(
                    (stmt.lineno, m, _call_arg_names(stmt.value)))
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            # loop targets alias the iterated names for release credit
            sources = set(_names_in(stmt.iter))
            for name in _names_in(stmt.target):
                self.alias.setdefault(name, set()).update(sources)
        if isinstance(stmt, ast.Try):
            for s in stmt.body:
                self._scan_stmt(s, protected=protected)
            handler_protects = bool(stmt.finalbody) or bool(stmt.handlers)
            for h in stmt.handlers:
                for s in h.body:
                    self._scan_stmt(s, protected=True)
            for s in stmt.orelse:
                self._scan_stmt(s, protected=protected)
            for s in stmt.finalbody:
                self._scan_stmt(s, protected=True)
            # a release in an except handler only covers the failure
            # path; pair it with the success-path release recorded by
            # the plain walk — both land in self.released/_protected
            _ = handler_protects
        else:
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.stmt):
                    self._scan_stmt(child, protected=protected)
        # expression-level scanning of this statement (calls, escapes)
        self._scan_expr_uses(stmt, protected=protected)

    def _closure(self, names: Iterable[str]) -> set:
        """Expand ``names`` through loop-target aliases (worklist)."""
        out, todo = set(), list(names)
        while todo:
            n = todo.pop()
            if n in out:
                continue
            out.add(n)
            todo.extend(self.alias.get(n, ()))
        return out

    def _scan_assign(self, targets: Sequence[ast.expr],
                     value: ast.expr) -> None:
        meth, args = None, set()
        for n in ast.walk(value):
            if isinstance(n, ast.Call):
                m = _call_method_name(n)
                if m in ACQUIRE_METHODS:
                    meth, args = m, _call_arg_names(n)
                    break
        for tgt in targets:
            if isinstance(tgt, ast.Name):
                if meth is not None:
                    self.acquired[tgt.id] = (tgt.lineno, meth)
                    self.acquire_args.setdefault(tgt.id, set()).update(args)
                else:
                    # rebound acquires keep their audit; the new binding
                    # carries ownership of the names it was built from
                    self.built_from.setdefault(tgt.id, set()).update(
                        _names_in(value))
            elif isinstance(tgt, (ast.Attribute, ast.Subscript)):
                # stored on an owner object/container: escapes
                for name in _names_in(value):
                    self.escaped.add(name)

    def _scan_expr_uses(self, stmt: ast.stmt, *, protected: bool) -> None:
        if isinstance(stmt, (ast.Return, ast.Expr)) \
                and isinstance(getattr(stmt, "value", None), ast.AST):
            if isinstance(stmt, ast.Return):
                for name in _names_in(stmt):
                    self.escaped.add(name)
                return
        for n in ast.walk(stmt):
            if isinstance(n, (ast.Yield, ast.YieldFrom)) and n.value:
                for name in _names_in(n.value):
                    self.escaped.add(name)
            if isinstance(n, ast.Call):
                m = _call_method_name(n)
                if m in RELEASE_METHODS:
                    arg_names = _call_arg_names(n)
                    used = set(arg_names)
                    # ``lease.release()`` form: receiver is the lease
                    if isinstance(n.func, ast.Attribute) \
                            and isinstance(n.func.value, ast.Name):
                        used.add(n.func.value.id)
                    for name in self._closure(used):
                        self.released.add(name)
                        if protected:
                            self.protected.add(name)
                    if protected:
                        self.protected_args.update(self._closure(arg_names))
                elif m in ("append", "add", "setdefault", "put"):
                    # handed to a long-lived container: ownership moves
                    for arg in list(n.args) + [k.value for k in n.keywords]:
                        for name in _names_in(arg):
                            self.escaped.add(name)


def _check_tl001(tree: ast.AST, path: str) -> List[LintViolation]:
    out: List[LintViolation] = []

    def visit(node: ast.AST, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                out.extend(_FuncLeaseAudit(child, path, qual).run())
                visit(child, f"{qual}.")
            elif isinstance(child, ast.ClassDef):
                visit(child, f"{prefix}{child.name}.")
            else:
                visit(child, prefix)

    visit(tree, "")
    return out


# -- TL002: wall-clock discipline --------------------------------------------


def _check_tl002(tree: ast.AST, path: str) -> List[LintViolation]:
    if not path.startswith("src/repro/"):
        return []
    rel = path[len("src/repro/"):]
    if not rel.startswith(CLOCKED_PACKAGES):
        return []
    if rel in WALL_CLOCK_ALLOWLIST:
        return []
    # names imported straight from the time module count too
    from_time: set = set()
    for n in ast.walk(tree):
        if isinstance(n, ast.ImportFrom) and n.module == "time":
            for a in n.names:
                from_time.add(a.asname or a.name)
    out = []
    for n in ast.walk(tree):
        if not isinstance(n, ast.Call):
            continue
        f = n.func
        name = None
        if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
                and f.value.id == "time" and f.attr in WALL_CLOCK_ATTRS:
            name = f"time.{f.attr}"
        elif isinstance(f, ast.Name) and f.id in from_time \
                and f.id in WALL_CLOCK_ATTRS:
            name = f.id
        if name is not None:
            out.append(LintViolation(
                rule="TL002", path=path, line=n.lineno,
                symbol=_enclosing(tree, n), detail=name,
                message=f"wall-clock read `{name}()` in the deterministic "
                        f"core — inject `repro.obs.clock` instead"))
    return out


# -- TL003: kernel-mode discipline -------------------------------------------


def _check_tl003(tree: ast.AST, path: str) -> List[LintViolation]:
    if path.startswith("src/repro/kernels/"):
        return []
    out = []
    for n in ast.walk(tree):
        if not isinstance(n, ast.Call):
            continue
        for kw in n.keywords:
            if kw.arg == "interpret":
                out.append(LintViolation(
                    rule="TL003", path=path, line=n.lineno,
                    symbol=_enclosing(tree, n), detail="interpret=",
                    message="`interpret=` at a call site outside "
                            "kernels/ — mode resolution belongs to "
                            "kernels/ops.py::resolve_mode"))
            elif kw.arg in ("mode", "kernel_mode") \
                    and isinstance(kw.value, ast.Constant) \
                    and kw.value.value in INTERPRET_MODE_LITERALS:
                out.append(LintViolation(
                    rule="TL003", path=path, line=n.lineno,
                    symbol=_enclosing(tree, n),
                    detail=f"{kw.arg}={kw.value.value!r}",
                    message=f"interpret-mode literal "
                            f"`{kw.arg}={kw.value.value!r}` outside "
                            f"kernels/ — use resolve_mode / env"))
    return out


# -- TL004: tenant threading -------------------------------------------------


def _check_tl004(tree: ast.AST, path: str) -> List[LintViolation]:
    if not path.startswith("src/repro/"):
        return []
    rel = path[len("src/repro/"):]
    if not rel.startswith(TENANT_PACKAGES):
        return []
    out = []
    for n in ast.walk(tree):
        if not isinstance(n, ast.Call):
            continue
        m = _call_method_name(n)
        if m not in TENANT_METHODS or not isinstance(n.func, ast.Attribute):
            continue
        kws = {k.arg for k in n.keywords}
        if "tenant" in kws or None in kws:     # **kwargs may carry it
            continue
        out.append(LintViolation(
            rule="TL004", path=path, line=n.lineno,
            symbol=_enclosing(tree, n), detail=m,
            message=f"`.{m}(...)` without `tenant=` falls back to the "
                    f"untenanted sentinel — thread the requester's "
                    f"tenant through"))
    return out


# -- TL005: swallowed pressure -----------------------------------------------


def _check_tl005(tree: ast.AST, path: str) -> List[LintViolation]:
    out = []
    for n in ast.walk(tree):
        if not isinstance(n, ast.ExceptHandler):
            continue
        caught: List[str] = []
        if n.type is None:
            caught = ["<bare>"]
        else:
            types = (n.type.elts if isinstance(n.type, ast.Tuple)
                     else [n.type])
            for t in types:
                if isinstance(t, ast.Name):
                    caught.append(t.id)
                elif isinstance(t, ast.Attribute):
                    caught.append(t.attr)
        swallows = all(
            isinstance(s, ast.Pass)
            or (isinstance(s, ast.Expr)
                and isinstance(s.value, ast.Constant)
                and s.value.value is Ellipsis)
            for s in n.body)
        if "<bare>" in caught:
            out.append(LintViolation(
                rule="TL005", path=path, line=n.lineno,
                symbol=_enclosing(tree, n), detail="bare-except",
                message="bare `except:` hides PoolExhausted and "
                        "KeyboardInterrupt alike — name the exception"))
        elif swallows and any(c in ("PoolExhausted", "Exception",
                                    "BaseException") for c in caught):
            what = "/".join(caught)
            out.append(LintViolation(
                rule="TL005", path=path, line=n.lineno,
                symbol=_enclosing(tree, n), detail=f"swallow:{what}",
                message=f"`except {what}` with an empty body swallows "
                        f"memory pressure — handle or re-raise"))
    return out


# -- driver ------------------------------------------------------------------

_RULES = (_check_tl001, _check_tl002, _check_tl003, _check_tl004,
          _check_tl005)

_ENCLOSING_CACHE: Dict[int, Dict[int, str]] = {}


def _enclosing(tree: ast.AST, node: ast.AST) -> str:
    """Qualname of the function containing ``node`` ("" = module)."""
    cache = _ENCLOSING_CACHE.get(id(tree))
    if cache is None:
        cache = {}

        def index(parent: ast.AST, prefix: str) -> None:
            for child in ast.iter_child_nodes(parent):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    qual = f"{prefix}{child.name}"
                    for sub in ast.walk(child):
                        cache.setdefault(id(sub), qual)
                    index(child, f"{qual}.")
                elif isinstance(child, ast.ClassDef):
                    index(child, f"{prefix}{child.name}.")
                else:
                    index(child, prefix)

        index(tree, "")
        _ENCLOSING_CACHE[id(tree)] = cache
    return cache.get(id(node), "")


def lint_source(src: str, path: str = "<string>",
                rules: Optional[Sequence[str]] = None) -> List[LintViolation]:
    """Lint one source string.  ``path`` drives the scope rules (TL002/
    TL004 only fire inside their packages); pass a repo-relative path
    like ``src/repro/serving/engine.py`` to get production behaviour.
    ``rules`` restricts to a subset of rule ids (None = all)."""
    tree = ast.parse(src, filename=path)
    out: List[LintViolation] = []
    try:
        for rule_fn in _RULES:
            found = rule_fn(tree, path)
            if rules is not None:
                found = [v for v in found if v.rule in rules]
            out.extend(found)
    finally:
        _ENCLOSING_CACHE.pop(id(tree), None)
    return sorted(out, key=lambda v: (v.path, v.line, v.rule))


def lint_paths(paths: Sequence[str], *, repo_root: str = ".",
               rules: Optional[Sequence[str]] = None) -> List[LintViolation]:
    """Lint files given as paths relative to ``repo_root``."""
    import os
    out: List[LintViolation] = []
    for rel in paths:
        full = os.path.join(repo_root, rel)
        with open(full) as f:
            src = f.read()
        out.extend(lint_source(src, rel.replace(os.sep, "/"), rules=rules))
    return out


def lint_tree(root: str = "src/repro", *, repo_root: str = ".",
              rules: Optional[Sequence[str]] = None) -> List[LintViolation]:
    """Lint every ``.py`` under ``root`` (relative to ``repo_root``)."""
    import os
    paths = []
    base = os.path.join(repo_root, root)
    for dirpath, _dirnames, filenames in os.walk(base):
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                full = os.path.join(dirpath, fn)
                paths.append(os.path.relpath(full, repo_root))
    return lint_paths(sorted(paths), repo_root=repo_root, rules=rules)


# -- ratchet baseline --------------------------------------------------------


def load_baseline(path: str) -> Dict[str, int]:
    """Baseline file -> {violation key: grandfathered count}."""
    with open(path) as f:
        doc = json.load(f)
    assert doc.get("schema") == "telint.baseline/v1", doc.get("schema")
    return {str(k): int(v) for k, v in doc["violations"].items()}


def dump_baseline(violations: Sequence[LintViolation], path: str) -> None:
    counts: Dict[str, int] = {}
    for v in violations:
        counts[v.key] = counts.get(v.key, 0) + 1
    with open(path, "w") as f:
        json.dump({"schema": "telint.baseline/v1",
                   "violations": dict(sorted(counts.items()))},
                  f, indent=2, sort_keys=False)
        f.write("\n")


def ratchet(violations: Sequence[LintViolation], baseline: Dict[str, int],
            ) -> Tuple[List[LintViolation], List[str]]:
    """(new violations not covered by the baseline, stale baseline keys
    that no longer fire — candidates for --update-baseline)."""
    counts: Dict[str, List[LintViolation]] = {}
    for v in violations:
        counts.setdefault(v.key, []).append(v)
    new: List[LintViolation] = []
    for key, vs in counts.items():
        allowed = baseline.get(key, 0)
        if len(vs) > allowed:
            new.extend(vs[allowed:])
    stale = [k for k, c in baseline.items()
             if len(counts.get(k, ())) < c]
    return sorted(new, key=lambda v: (v.path, v.line, v.rule)), sorted(stale)
