"""telint: repo-specific static lint + dynamic trace invariant checking.

Two coordinated halves guard the discipline TeleRAG's correctness
rides on (docs/ANALYSIS.md):

* ``lint`` — AST rules TL001–TL005 over ``src/repro`` (lease leaks,
  wall-clock reads outside the event clock, kernel-mode literals at
  call sites, dropped tenant threading, swallowed ``PoolExhausted``),
  ratcheted against ``analysis/baseline.json`` in CI.
* ``invariants`` — replays a ``FlightRecorder`` stream and checks the
  happens-before partial orders (transfer issue→land→use,
  admission→dispatch, lease→release, kv-acquire→decode→kv-release)
  plus conservation (no double release, no negative outstanding
  pages/bytes, leases drained at end of run).

``lint`` is stdlib-only on purpose: CI's ratchet step must not need
jax/numpy installed.
"""

from repro.analysis.lint import LintViolation, lint_paths, lint_source
from repro.analysis.invariants import (InvariantReport, InvariantViolation,
                                       check_events, check_recorder,
                                       events_from_jsonl,
                                       events_from_perfetto)

__all__ = [
    "LintViolation", "lint_paths", "lint_source",
    "InvariantReport", "InvariantViolation", "check_events",
    "check_recorder", "events_from_jsonl", "events_from_perfetto",
]
