"""Happens-before invariant checker over a ``FlightRecorder`` stream.

Replays the typed event stream (live ``TraceEvent`` objects, or plain
dicts loaded from the lossless JSONL export) and verifies the partial
orders TeleRAG's overlap correctness rides on:

  * **transfer issue → land → use**: a wave's ``retrieve`` span must
    not start before its correlated transfer's modeled landing — a
    violation is exactly the use-before-land race lookahead retrieval
    exists to avoid (pages searched before the H2D copy finished).
  * **admission admit → dispatch**: a wave that moved prefetch bytes
    (``wave.dispatch`` with a transfer id) must have a prior admission
    decision for the same (replica, wave) — reservations are taken
    before pages move, never retroactively.
  * **lease → release conservation**: per (replica, owner category)
    the outstanding page/byte balance from ``pool.lease`` /
    ``pool.release`` edges never goes negative (double release /
    over-release) and — in drained mode — ends at zero for the owner
    categories the caller says must drain.
  * **kv acquire → decode → release**: decode steps only appear after
    a KV acquire on that replica (when the replica uses managed KV at
    all), and KV acquire/release edges balance.
  * **paged lease discipline**: events carrying a ``lease_id`` (the
    block-table decode path) obey per-(replica, lease) ordering —
    ``kv.append`` only between that lease's ``kv.acquire`` and
    ``kv.release``, never past the lease's ``max_len`` capacity — and
    page conservation: the slab page count returned at ``kv.release``
    equals the count taken at ``kv.acquire``, and a lease id is never
    opened twice (ids are process-global and unique by construction).
  * **stall → resume**: in drained mode no request may end its life
    parked (``pressure_stall`` as its last lifecycle mark), and every
    ``admission.stall`` needs a matching resume.

The checker is a pure function of the event stream: no engine state,
no clocks — so it runs identically on a live recorder (the pytest
fixture in tests/conftest.py), on a JSONL file (``tools/telint.py
--trace``), or on a Perfetto export's partial reconstruction
(``events_from_perfetto`` — span/transfer/admission subset only; pool
conservation needs the JSONL stream, whose events keep owner/pages).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field, is_dataclass, asdict
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

EPS = 1e-9

# violation kinds (docs/ANALYSIS.md glossary)
USE_BEFORE_LAND = "use_before_land"
DISPATCH_WITHOUT_ADMISSION = "dispatch_without_admission"
DOUBLE_RELEASE = "double_release"
LEDGER_DRIFT = "ledger_drift"
KV_DOUBLE_RELEASE = "kv_double_release"
KV_LEASE_REUSE = "kv_lease_reuse"
KV_APPEND_OUT_OF_LEASE = "kv_append_out_of_lease"
KV_APPEND_OVERFLOW = "kv_append_overflow"
KV_PAGE_CONSERVATION = "kv_page_conservation"
KV_SPLICE_OUT_OF_LEASE = "kv_splice_out_of_lease"
KV_RECYCLE_MISMATCH = "kv_recycle_mismatch"
CHUNK_PIN_BEFORE_LOAD = "chunk_pin_before_load"
CHUNK_UNPIN_WITHOUT_PIN = "chunk_unpin_without_pin"
CHUNK_EVICT_WHILE_PINNED = "chunk_evict_while_pinned"
CHUNK_PAGE_CONSERVATION = "chunk_page_conservation"
DECODE_WITHOUT_KV = "decode_without_kv"
TRANSFER_INVERTED = "transfer_inverted"
LIFECYCLE_DISORDER = "lifecycle_disorder"
STALL_WITHOUT_RESUME = "stall_without_resume"
HELD_AT_DRAIN = "held_at_drain"


@dataclass(frozen=True)
class InvariantViolation:
    kind: str
    message: str
    t: float = 0.0
    replica: int = -1
    request_id: int = -1
    wave_id: int = -1

    def render(self) -> str:
        where = f"replica {self.replica}" if self.replica >= 0 else "server"
        ids = "".join(
            f" {k}={v}" for k, v in (("req", self.request_id),
                                     ("wave", self.wave_id)) if v >= 0)
        return f"[{self.kind}] t={self.t:.6f} {where}{ids}: {self.message}"


@dataclass
class InvariantReport:
    violations: List[InvariantViolation] = field(default_factory=list)
    checked_events: int = 0
    stats: Dict[str, int] = field(default_factory=dict)
    # leftover balances at end of stream (informational unless the
    # owner category was passed in ``must_drain``)
    outstanding: Dict[str, int] = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.violations

    def of(self, kind: str) -> List[InvariantViolation]:
        return [v for v in self.violations if v.kind == kind]

    def summary(self) -> str:
        head = (f"invariants: {self.checked_events} events, "
                f"{len(self.violations)} violation(s)")
        if not self.violations:
            return head + " — OK"
        by_kind: Dict[str, int] = {}
        for v in self.violations:
            by_kind[v.kind] = by_kind.get(v.kind, 0) + 1
        lines = [head]
        lines += [f"  {k}: {n}" for k, n in sorted(by_kind.items())]
        lines += ["  " + v.render() for v in self.violations[:20]]
        if len(self.violations) > 20:
            lines.append(f"  ... {len(self.violations) - 20} more")
        return "\n".join(lines)


# -- event normalization -----------------------------------------------------


def _norm(ev) -> Dict[str, object]:
    """TraceEvent dataclass or dict -> plain dict with a ``kind`` key."""
    if isinstance(ev, dict):
        return ev
    if is_dataclass(ev):
        return asdict(ev)
    raise TypeError(f"not a trace event: {ev!r}")


def events_from_jsonl(path: str) -> List[Dict[str, object]]:
    """Load the lossless JSONL stream (``repro.obs.export.write_jsonl``)
    back into plain event dicts, emission order preserved."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def events_from_perfetto(doc: Dict) -> List[Dict[str, object]]:
    """Partial reconstruction from a Perfetto export: ``retrieve``
    spans, transfers, wave/admission instants and request marks — the
    subset needed for the race/ordering checks.  Pool conservation
    checks need the JSONL stream (the Perfetto export collapses pool
    edges into counter tracks)."""
    out: List[Dict[str, object]] = []
    us = 1e-6

    def replica(ev) -> int:
        pid = ev.get("pid", -1)
        return -1 if pid == 9999 else int(pid)

    for ev in doc.get("traceEvents", []):
        ph, name = ev.get("ph"), ev.get("name", "")
        args = ev.get("args", {}) or {}
        t = float(ev.get("ts", 0.0)) * us
        if ph == "X" and ev.get("cat") == "span":
            out.append({"kind": "span", "name": name, "t": t,
                        "dur": float(ev.get("dur", 0.0)) * us,
                        "replica": replica(ev),
                        "request_id": int(args.get("request_id", -1)),
                        "wave_id": int(args.get("wave_id", -1)),
                        "round_index": int(args.get("round", -1)),
                        "tenant": args.get("tenant", "shared")})
        elif ph == "X" and ev.get("cat") == "transfer":
            start = t
            end = start + float(ev.get("dur", 0.0)) * us
            issue_t = start - float(args.get("queued_us", 0.0)) * us
            base = {"replica": replica(ev),
                    "transfer_id": int(args.get("transfer_id", -1)),
                    "nbytes": int(args.get("nbytes", 0)),
                    "n_clusters": int(args.get("clusters", 0)),
                    "channel": int(args.get("channel", -1)),
                    "start_t": start, "end_t": end}
            out.append(dict(base, kind="transfer.issue", t=issue_t))
            out.append(dict(base, kind="transfer.land", t=end))
        elif ph == "i" and name.startswith("wave."):
            out.append({"kind": name, "t": t, "replica": replica(ev),
                        "wave_id": int(args.get("wave_id", -1)),
                        "size": int(args.get("size", 0)),
                        "transfer_id": int(args.get("transfer_id", -1)),
                        "nbytes": int(args.get("nbytes", 0)),
                        "request_ids": tuple(args.get("request_ids", ()))})
        elif ph == "i" and name.startswith("admission."):
            out.append({"kind": name, "t": t, "replica": replica(ev),
                        "wave_id": int(args.get("wave_id", -1)),
                        "owner": args.get("owner", ""),
                        "pages_requested": int(args.get("pages_requested", 0)),
                        "pages_granted": int(args.get("pages_granted", 0))})
        elif ph == "b" and ev.get("cat") == "request":
            out.append({"kind": "request", "label": "admit", "t": t,
                        "replica": replica(ev),
                        "request_id": int(ev.get("id", -1))})
        elif ph == "e" and ev.get("cat") == "request":
            out.append({"kind": "request", "label": "complete", "t": t,
                        "replica": replica(ev),
                        "request_id": int(ev.get("id", -1))})
        elif ph == "i" and name in ("pressure_stall", "pressure_resume"):
            out.append({"kind": "request", "label": name, "t": t,
                        "replica": replica(ev),
                        "request_id": int(args.get("request_id", -1))})
    # Perfetto documents are unordered per spec; restore time order with
    # a stable sort so "emission order" checks see a consistent stream
    out.sort(key=lambda e: e["t"])
    return out


# -- the checker -------------------------------------------------------------


def check_events(events: Iterable, *, drained: bool = False,
                 must_drain: Sequence[str] = (),
                 ) -> InvariantReport:
    """Verify the happens-before invariants over ``events`` (emission
    order).  ``drained=True`` additionally enforces end-of-run
    conditions: no request left parked, admission stalls all resumed,
    and zero outstanding pages for the owner categories in
    ``must_drain`` (e.g. ``("prefetch",)`` after a full eviction; KV
    and cache-protected residency legitimately persist)."""
    evs = [_norm(e) for e in events]
    rep = InvariantReport(checked_events=len(evs))
    v = rep.violations.append

    def g(e, key, default=None):
        return e.get(key, default)

    # -- pass 1: correlation maps -------------------------------------------
    # (replica, transfer_id) -> land time; transfer sanity on the way
    land_t: Dict[Tuple[int, int], float] = {}
    for e in evs:
        if g(e, "kind") == "transfer.issue":
            r, tid = int(g(e, "replica", -1)), int(g(e, "transfer_id", -1))
            start, end = float(g(e, "start_t", 0.0)), float(g(e, "end_t", 0.0))
            land_t[(r, tid)] = end
            if end < start - EPS:
                v(InvariantViolation(
                    TRANSFER_INVERTED, t=float(g(e, "t", 0.0)), replica=r,
                    message=f"transfer {tid} lands at {end:.6f} before its "
                            f"own start {start:.6f}"))
            if start < float(g(e, "t", 0.0)) - EPS:
                v(InvariantViolation(
                    TRANSFER_INVERTED, t=float(g(e, "t", 0.0)), replica=r,
                    message=f"transfer {tid} starts at {start:.6f} before "
                            f"its submit at {g(e, 't'):.6f}"))
        elif g(e, "kind") == "transfer.land":
            r, tid = int(g(e, "replica", -1)), int(g(e, "transfer_id", -1))
            # a land event may carry a fresher end_t than the issue
            land_t.setdefault((r, tid), float(g(e, "t", 0.0)))

    # (replica, wave_id) -> earliest admission decision time
    admit_t: Dict[Tuple[int, int], float] = {}
    for e in evs:
        if g(e, "kind") in ("admission.admit", "admission.cap"):
            key = (int(g(e, "replica", -1)), int(g(e, "wave_id", -1)))
            t = float(g(e, "t", 0.0))
            if key[1] >= 0 and (key not in admit_t or t < admit_t[key]):
                admit_t[key] = t

    # -- pass 2: per-wave dispatch ordering ---------------------------------
    # wave.dispatch with a transfer: members' retrieve spans must start
    # at/after the transfer's landing, and admission must precede it
    dispatch: Dict[Tuple[int, int], Dict[str, object]] = {}
    for e in evs:
        if g(e, "kind") == "wave.dispatch":
            r, w = int(g(e, "replica", -1)), int(g(e, "wave_id", -1))
            dispatch[(r, w)] = e
            tid = int(g(e, "transfer_id", -1))
            t = float(g(e, "t", 0.0))
            if tid >= 0:
                at = admit_t.get((r, w))
                if at is None:
                    v(InvariantViolation(
                        DISPATCH_WITHOUT_ADMISSION, t=t, replica=r,
                        wave_id=w,
                        message=f"wave {w} moved bytes (transfer {tid}) "
                                f"with no admission decision on record"))
                elif at > t + EPS:
                    v(InvariantViolation(
                        DISPATCH_WITHOUT_ADMISSION, t=t, replica=r,
                        wave_id=w,
                        message=f"wave {w} dispatched at {t:.6f} before "
                                f"its admission at {at:.6f}"))

    for e in evs:
        if g(e, "kind") == "span" and g(e, "name") == "retrieve":
            r, w = int(g(e, "replica", -1)), int(g(e, "wave_id", -1))
            d = dispatch.get((r, w))
            if d is None:
                continue
            tid = int(g(d, "transfer_id", -1))
            if tid < 0:
                continue
            lt = land_t.get((r, tid))
            start = float(g(e, "t", 0.0))
            if lt is not None and start < lt - EPS:
                v(InvariantViolation(
                    USE_BEFORE_LAND, t=start, replica=r,
                    request_id=int(g(e, "request_id", -1)), wave_id=w,
                    message=f"retrieve starts at {start:.6f} but wave "
                            f"{w}'s transfer {tid} lands at {lt:.6f} — "
                            f"pages searched before the copy finished"))

    # -- pass 3: conservation (pool / kv), emission order -------------------
    pages_out: Dict[Tuple[int, str], int] = {}
    bytes_out: Dict[Tuple[int, str], int] = {}
    kv_out: Dict[int, int] = {}
    kv_replicas = {int(g(e, "replica", -1)) for e in evs
                   if str(g(e, "kind", "")).startswith("kv.")}
    kv_seen: Dict[int, bool] = {}
    # paged-lease discipline, keyed (replica, lease_id) for lease_id>=0:
    # open leases carry their acquired page count + max_len capacity
    paged_open: Dict[Tuple[int, int], Dict[str, int]] = {}
    paged_done: set = set()
    # dense bucket recycling, per replica: a dense kv.release parks the
    # bucket (+1), a recycled kv.acquire reuses one (-1), kv.drop
    # returns one's bytes to the pool (-1) — the balance never dips
    # below zero, or recycling double-counted a bucket
    recycle_pool: Dict[int, int] = {}
    # chunk-KV residency discipline, keyed (replica, doc_id): load →
    # pin*/unpin* (balanced, pins tracked) → evict at pin count zero
    chunk_open: Dict[Tuple[int, int], Dict[str, int]] = {}
    chunk_loads = 0
    for e in evs:
        kind = str(g(e, "kind", ""))
        if kind in ("pool.lease", "pool.release"):
            key = (int(g(e, "replica", -1)), str(g(e, "owner", "")))
            sign = 1 if kind == "pool.lease" else -1
            pages_out[key] = pages_out.get(key, 0) + sign * int(
                g(e, "pages", 0))
            bytes_out[key] = bytes_out.get(key, 0) + sign * int(
                g(e, "nbytes", 0))
            if pages_out[key] < 0:
                v(InvariantViolation(
                    DOUBLE_RELEASE, t=float(g(e, "t", 0.0)),
                    replica=key[0],
                    message=f"owner {key[1]!r} released more pages than "
                            f"it leased (balance {pages_out[key]})"))
                pages_out[key] = 0        # report once per dip, not per event
            if bytes_out[key] < 0:
                v(InvariantViolation(
                    LEDGER_DRIFT, t=float(g(e, "t", 0.0)), replica=key[0],
                    message=f"owner {key[1]!r} byte balance went negative "
                            f"({bytes_out[key]}) — release bytes exceed "
                            f"lease bytes"))
                bytes_out[key] = 0
        elif kind == "kv.acquire":
            r = int(g(e, "replica", -1))
            kv_out[r] = kv_out.get(r, 0) + 1
            kv_seen[r] = True
            lid = int(g(e, "lease_id", -1))
            if lid >= 0:
                key = (r, lid)
                if key in paged_open or key in paged_done:
                    v(InvariantViolation(
                        KV_LEASE_REUSE, t=float(g(e, "t", 0.0)), replica=r,
                        message=f"lease {lid} acquired twice — paged lease "
                                f"ids are unique by construction"))
                else:
                    paged_open[key] = {"pages": int(g(e, "pages", 0)),
                                       "max_len": int(g(e, "max_len", 0))}
            elif g(e, "recycled", False):
                bal = recycle_pool.get(r, 0)
                if bal <= 0:
                    v(InvariantViolation(
                        KV_RECYCLE_MISMATCH, t=float(g(e, "t", 0.0)),
                        replica=r,
                        message="recycled kv.acquire with no bucket parked "
                                "by a prior dense kv.release"))
                else:
                    recycle_pool[r] = bal - 1
        elif kind == "kv.append":
            r = int(g(e, "replica", -1))
            lid = int(g(e, "lease_id", -1))
            t = float(g(e, "t", 0.0))
            st = paged_open.get((r, lid)) if lid >= 0 else None
            if st is None:
                v(InvariantViolation(
                    KV_APPEND_OUT_OF_LEASE, t=t, replica=r,
                    message=f"kv.append for lease {lid} outside its "
                            f"acquire→release window (not an open paged "
                            f"lease on this replica)"))
            elif st["max_len"] > 0 and int(g(e, "length", 0)) > st["max_len"]:
                v(InvariantViolation(
                    KV_APPEND_OVERFLOW, t=t, replica=r,
                    message=f"kv.append advanced lease {lid} to length "
                            f"{g(e, 'length')} past its max_len "
                            f"{st['max_len']} capacity"))
        elif kind == "kv.splice":
            r = int(g(e, "replica", -1))
            lid = int(g(e, "lease_id", -1))
            t = float(g(e, "t", 0.0))
            st = paged_open.get((r, lid)) if lid >= 0 else None
            if st is None:
                v(InvariantViolation(
                    KV_SPLICE_OUT_OF_LEASE, t=t, replica=r,
                    message=f"kv.splice for lease {lid} outside its "
                            f"acquire→release window — chunk pages attached "
                            f"to a block table that is not live"))
            else:
                # the splice legitimately raises the lease's capacity
                # (chunk pages prepend at page boundaries); later appends
                # are bounded by the raised max_len
                st["max_len"] = max(st["max_len"], int(g(e, "max_len", 0)))
        elif kind == "kv.drop":
            r = int(g(e, "replica", -1))
            bal = recycle_pool.get(r, 0)
            if bal <= 0:
                v(InvariantViolation(
                    KV_RECYCLE_MISMATCH, t=float(g(e, "t", 0.0)), replica=r,
                    message="kv.drop with no bucket parked by a prior "
                            "dense kv.release"))
            else:
                recycle_pool[r] = bal - 1
        elif kind == "kv.release":
            r = int(g(e, "replica", -1))
            kv_out[r] = kv_out.get(r, 0) - 1
            if kv_out[r] < 0:
                v(InvariantViolation(
                    KV_DOUBLE_RELEASE, t=float(g(e, "t", 0.0)), replica=r,
                    message="kv.release without a matching kv.acquire"))
                kv_out[r] = 0
            lid = int(g(e, "lease_id", -1))
            if lid < 0:
                recycle_pool[r] = recycle_pool.get(r, 0) + 1
            if lid >= 0:
                key = (r, lid)
                st = paged_open.pop(key, None)
                t = float(g(e, "t", 0.0))
                if st is None:
                    v(InvariantViolation(
                        KV_DOUBLE_RELEASE, t=t, replica=r,
                        message=f"kv.release for lease {lid} that is not "
                                f"open (double release or never acquired)"))
                else:
                    paged_done.add(key)
                    rel = int(g(e, "pages", 0))
                    if rel != st["pages"]:
                        v(InvariantViolation(
                            KV_PAGE_CONSERVATION, t=t, replica=r,
                            message=f"lease {lid} released {rel} slab "
                                    f"pages but acquired {st['pages']} — "
                                    f"block-table pages leaked or "
                                    f"double-counted"))
        elif kind in ("chunk.load", "chunk.pin", "chunk.unpin",
                      "chunk.evict"):
            r = int(g(e, "replica", -1))
            d = int(g(e, "doc_id", -1))
            t = float(g(e, "t", 0.0))
            key = (r, d)
            st = chunk_open.get(key)
            if kind == "chunk.load":
                chunk_loads += 1
                if st is not None:
                    v(InvariantViolation(
                        CHUNK_PAGE_CONSERVATION, t=t, replica=r,
                        message=f"chunk {d} loaded twice without an "
                                f"intervening evict — {st['pages']} resident "
                                f"pages double-counted"))
                chunk_open[key] = {"pages": int(g(e, "pages", 0)), "pins": 0}
            elif kind == "chunk.pin":
                if st is None:
                    # the splice-before-land race: a block table is about
                    # to reference pages that were never landed
                    v(InvariantViolation(
                        CHUNK_PIN_BEFORE_LOAD, t=t, replica=r,
                        message=f"chunk {d} pinned before any chunk.load — "
                                f"splice would reference pages not on "
                                f"device"))
                else:
                    st["pins"] += 1
            elif kind == "chunk.unpin":
                if st is None or st["pins"] <= 0:
                    v(InvariantViolation(
                        CHUNK_UNPIN_WITHOUT_PIN, t=t, replica=r,
                        message=f"chunk {d} unpinned with no outstanding "
                                f"pin"))
                else:
                    st["pins"] -= 1
            else:                                  # chunk.evict
                if st is None:
                    v(InvariantViolation(
                        CHUNK_PAGE_CONSERVATION, t=t, replica=r,
                        message=f"chunk {d} evicted but never loaded"))
                else:
                    if st["pins"] > 0:
                        v(InvariantViolation(
                            CHUNK_EVICT_WHILE_PINNED, t=t, replica=r,
                            message=f"chunk {d} evicted while pinned "
                                    f"({st['pins']} pins) — spilled pages "
                                    f"out from under a live block table"))
                    rel = int(g(e, "pages", 0))
                    if rel != st["pages"]:
                        v(InvariantViolation(
                            CHUNK_PAGE_CONSERVATION, t=t, replica=r,
                            message=f"chunk {d} evicted {rel} pages but "
                                    f"loaded {st['pages']}"))
                    del chunk_open[key]
        elif kind == "decode":
            r = int(g(e, "replica", -1))
            if r in kv_replicas and not kv_seen.get(r):
                v(InvariantViolation(
                    DECODE_WITHOUT_KV, t=float(g(e, "t", 0.0)), replica=r,
                    request_id=int(g(e, "request_id", -1)),
                    message="decode step recorded before any kv.acquire "
                            "on this replica"))

    # -- pass 4: request lifecycle ------------------------------------------
    marks: Dict[Tuple[int, str], Tuple[float, str]] = {}
    first: Dict[Tuple[int, str], Dict[str, float]] = {}
    for e in evs:
        if g(e, "kind") != "request":
            continue
        rid = int(g(e, "request_id", -1))
        tenant = str(g(e, "tenant", "shared"))
        label = str(g(e, "label", ""))
        t = float(g(e, "t", 0.0))
        key = (rid, tenant)
        marks[key] = (t, label)
        first.setdefault(key, {}).setdefault(label, t)
    for (rid, _tenant), labels in first.items():
        a, c = labels.get("admit"), labels.get("complete")
        if a is not None and c is not None and c < a - EPS:
            v(InvariantViolation(
                LIFECYCLE_DISORDER, t=c, request_id=rid,
                message=f"request {rid} completes at {c:.6f} before its "
                        f"admit at {a:.6f}"))

    # -- pass 5: drained-only end conditions --------------------------------
    if drained:
        for (rid, _tenant), (t, label) in sorted(marks.items()):
            if label == "pressure_stall":
                v(InvariantViolation(
                    STALL_WITHOUT_RESUME, t=t, request_id=rid,
                    message=f"request {rid} ends its life parked "
                            f"(last mark is pressure_stall)"))
        stalls = sum(1 for e in evs if g(e, "kind") == "admission.stall")
        resumes = sum(1 for e in evs if g(e, "kind") == "admission.resume")
        if stalls > resumes:
            v(InvariantViolation(
                STALL_WITHOUT_RESUME, t=0.0,
                message=f"{stalls} admission stalls but only {resumes} "
                        f"resumes — parked waves never woke"))
        for (r, owner), bal in sorted(pages_out.items()):
            if owner in must_drain and bal > 0:
                v(InvariantViolation(
                    HELD_AT_DRAIN, replica=r,
                    message=f"owner {owner!r} still holds {bal} pages "
                            f"after drain"))
        for r, bal in sorted(kv_out.items()):
            if "kv" in must_drain and bal > 0:
                v(InvariantViolation(
                    HELD_AT_DRAIN, replica=r,
                    message=f"{bal} kv lease(s) still outstanding after "
                            f"drain"))
        if "kv" in must_drain:
            for (r, lid), st in sorted(paged_open.items()):
                v(InvariantViolation(
                    HELD_AT_DRAIN, replica=r,
                    message=f"paged lease {lid} still open after drain "
                            f"({st['pages']} slab pages held)"))
        if "chunk_kv" in must_drain:
            for (r, d), st in sorted(chunk_open.items()):
                v(InvariantViolation(
                    HELD_AT_DRAIN, replica=r,
                    message=f"chunk {d} still resident after drain "
                            f"({st['pages']} pages, {st['pins']} pins)"))

    rep.outstanding = {f"r{r}:{o}": bal
                       for (r, o), bal in sorted(pages_out.items()) if bal}
    rep.outstanding.update({f"r{r}:kv-leases": bal
                            for r, bal in sorted(kv_out.items()) if bal})
    rep.stats = {
        "transfers": len(land_t),
        "waves_dispatched": len(dispatch),
        "requests": len(first),
        "paged_leases": len(paged_done) + len(paged_open),
        "chunk_loads": chunk_loads,
        "pool_edges": sum(1 for e in evs
                          if str(g(e, "kind", "")).startswith("pool.")),
    }
    return rep


def check_recorder(rec, **kwargs) -> InvariantReport:
    """Convenience: run the checker on a live ``FlightRecorder``.  A
    recorder that dropped events (capacity ring) cannot satisfy
    conservation — its truncated window is skipped with an OK report."""
    if getattr(rec, "dropped", 0):
        return InvariantReport(checked_events=0,
                               stats={"skipped_dropped": rec.dropped})
    return check_events(rec.events, **kwargs)
