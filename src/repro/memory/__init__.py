"""Unified paged device-memory subsystem: one HBM arbiter per replica.

``DevicePagePool`` (slab allocator: leases, refcounts, reservations,
block tables, per-tenant floors/caps via ``TenantShare``) +
``MemoryLedger`` (byte-accurate per-category and per-tenant accounting)
+ ``AdmissionController`` (tenant-scoped reserve/stall/spill decisions
for waves).  The prefetch buffer and the KV cache both draw from the
same pool, so retrieval state and generation state finally compete for
— and are accounted against — the same bytes.

See docs/TELEMETRY.md for the ledger-snapshot and admission-stats
field reference.
"""

from repro.memory.admission import (AdmissionController, AdmissionStats,
                                    AdmissionTicket)
from repro.memory.ledger import MemoryLedger
from repro.memory.pool import (DevicePagePool, PageLease, PoolExhausted,
                               Reservation, TenantShare)

__all__ = [
    "AdmissionController", "AdmissionStats", "AdmissionTicket",
    "MemoryLedger",
    "DevicePagePool", "PageLease", "PoolExhausted", "Reservation",
    "TenantShare",
]
