"""Admission control over the shared device page pool.

A wave's lookahead plan must *reserve* its page headroom up front; the
alternative — the planner silently clamping the plan to whatever slots
happen to be free — is exactly the failure mode the ROADMAP names ("the
planner stalls when a prior wave fills the buffer").  The controller
makes the reserve/stall/spill decision explicit:

  1. **reserve** — if the pool can promise the pages, hand back a
     ticket wrapping a ``Reservation``; allocation consumes it and
     ``commit()`` returns the unused remainder.
  2. **spill** — under pressure, first reclaim *evictable* pages (cold,
     unpinned cluster residency) through the spill hook, then retry the
     reservation.  Spilling is a recorded decision, not a side effect.
  3. **stall** — if pressure comes from pages that future events will
     free (another wave's pins, KV leases, outstanding reservations),
     return ``None``: the caller parks the wave ``PRESSURE_STALLED`` on
     the runtime's event queue and retries on page-free events.
  4. **cap** — when *nothing* outstanding will ever free pages (the
     plan simply exceeds the pool), grant what exists and mark the
     ticket ``capped`` so telemetry shows the shortfall.  This is the
     progress guarantee: a stall with no possible waker would deadlock.

Multi-tenant pools add two rules.  Admission is **tenant-scoped**: a
ticket reserves against ``pool.reservable_pages_for(tenant)``, so one
tenant's burst can never consume another tenant's unclaimed floor.  And
the spill hook is handed a **protect set**: residency belonging to
tenants at or under their guaranteed floor is never evicted to make
room for someone else's burst — spill victims come only from over-floor
(or untenanted) holders.

The controller never moves bytes itself; it only arbitrates the pool.
"""

from __future__ import annotations

import inspect
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.memory.pool import DevicePagePool, Reservation
from repro.obs.recorder import AdmissionEvent, FlightRecorder


@dataclass
class AdmissionStats:
    """Counters for one admission domain (a replica, or one tenant's
    slice of it).  ``*_pages`` fields count whole page slots; the rest
    count admit() decisions."""

    admitted: int = 0                # tickets granted with full headroom
    stalled: int = 0                 # admit() refusals that parked a wave
    resumed: int = 0                 # parked waves re-admitted later
    capped: int = 0                  # tickets granted below the request
    spilled_pages: int = 0           # pages reclaimed by the spill hook
    shortfall_pages: int = 0         # requested-minus-granted across caps


@dataclass(eq=False)
class AdmissionTicket:
    """One granted admission: the wave may allocate up to its
    reservation (``pages_granted`` pages); ``commit()`` after dispatch
    returns the remainder.  ``tenant`` is who the pages are charged to."""

    ticket_id: int
    owner: str
    pages_requested: int
    pages_granted: int
    reservation: Optional[Reservation]
    capped: bool = False
    spilled_pages: int = 0
    tenant: str = "shared"


class AdmissionController:
    """Arbitrates the pool for wave admission: reserve / spill / stall /
    cap, with per-tenant floors honored and per-tenant stats kept next
    to the replica-wide ``stats``."""

    def __init__(self, pool: DevicePagePool, *,
                 spill: Optional[Callable[..., object]] = None):
        """``spill(target_free_pages, protect=None)`` should try to raise
        the pool's physically-free page count to the target by evicting
        cold, unpinned residency (best effort), skipping any cluster in
        ``protect`` (residency of tenants at/under their floor).  Hooks
        with the legacy single-argument signature are still accepted."""
        self.pool = pool
        self.spill = spill
        self._spill_takes_protect = False
        if spill is not None:
            try:
                params = inspect.signature(spill).parameters
                self._spill_takes_protect = (
                    "protect" in params
                    or any(p.kind is p.VAR_KEYWORD
                           for p in params.values()))
            except (TypeError, ValueError):
                pass
        self.stats = AdmissionStats()
        self.per_tenant: Dict[str, AdmissionStats] = {}
        self._ids = itertools.count()
        # parked waves: (key, pages_requested, tenant)
        self.parked: List[Tuple[object, int, str]] = []
        # flight-recorder lane (attached by the owning engine/server);
        # decisions are stamped at recorder.now — admit() takes no clock
        self.recorder: Optional[FlightRecorder] = None
        self.replica_id = -1

    def _record(self, kind: str, owner: str, requested: int, granted: int,
                tenant: str, *, wave_id: int = -1,
                spilled: int = 0) -> None:
        rec = self.recorder
        if rec is not None:
            rec.emit(AdmissionEvent(
                t=rec.now, kind=kind, replica=self.replica_id,
                tenant=tenant, wave_id=wave_id, owner=owner,
                pages_requested=requested, pages_granted=granted,
                spilled_pages=spilled))

    def _tstats(self, tenant: str) -> AdmissionStats:
        """The per-tenant stats slice (created on first touch)."""
        if tenant not in self.per_tenant:
            self.per_tenant[tenant] = AdmissionStats()
        return self.per_tenant[tenant]

    # -- decision -----------------------------------------------------------
    def admit(self, npages: int, owner: str, *, can_wait: bool = True,
              tenant: str = "shared",
              wave_id: int = -1) -> Optional[AdmissionTicket]:
        """Reserve ``npages`` of headroom for ``tenant``.  None = park
        and retry on a page-free event (only when ``can_wait`` and a
        future free is possible); otherwise the grant may be
        spilled-into or capped.  ``wave_id`` only correlates the
        decision's trace event with the requesting wave."""
        npages = int(npages)
        tstats = self._tstats(tenant)
        res = self.pool.reserve(npages, owner, tenant=tenant)
        spilled = 0
        if res is None and self.spill is not None and npages > 0:
            before = self.pool.free_pages()
            # target enough physical frees to cover others' reservations too
            self._run_spill(npages + self.pool.reserved_pages(), tenant)
            spilled = self.pool.free_pages() - before
            self.stats.spilled_pages += spilled
            tstats.spilled_pages += spilled
            res = self.pool.reserve(npages, owner, tenant=tenant)
            if spilled > 0:
                self._record("admission.spill", owner, npages, 0, tenant,
                             wave_id=wave_id, spilled=spilled)
        if res is None:
            # parking is only sound if a future free could EVER satisfy
            # the request — a plan above the tenant's reachable ceiling
            # (its burst cap / others' floors) must cap now, not starve
            # on page-free retries until the event heap drains
            reachable = npages <= self.pool.tenant_ceiling(tenant)
            if can_wait and reachable and self.holds_pending_release():
                self.stats.stalled += 1
                tstats.stalled += 1
                self._record("admission.stall", owner, npages, 0, tenant,
                             wave_id=wave_id)
                return None
            granted = max(0, self.pool.reservable_pages_for(tenant))
            res = (self.pool.reserve(granted, owner, tenant=tenant)
                   if granted else None)
            self.stats.capped += 1
            tstats.capped += 1
            self.stats.shortfall_pages += npages - granted
            tstats.shortfall_pages += npages - granted
            self._record("admission.cap", owner, npages, granted, tenant,
                         wave_id=wave_id, spilled=spilled)
            return AdmissionTicket(
                ticket_id=next(self._ids), owner=owner,
                pages_requested=npages, pages_granted=granted,
                reservation=res, capped=True, spilled_pages=spilled,
                tenant=tenant)
        self.stats.admitted += 1
        tstats.admitted += 1
        self._record("admission.admit", owner, npages, npages, tenant,
                     wave_id=wave_id, spilled=spilled)
        return AdmissionTicket(
            ticket_id=next(self._ids), owner=owner, pages_requested=npages,
            pages_granted=npages, reservation=res, spilled_pages=spilled,
            tenant=tenant)

    def _run_spill(self, target: int, tenant: str) -> None:
        """Invoke the spill hook with the floor-protect set (falling
        back to the legacy one-argument hook signature, detected once
        at construction)."""
        if self._spill_takes_protect:
            self.spill(target, protect=self.spill_protect(tenant))
        else:
            self.spill(target)

    def spill_protect(self, tenant: str) -> Optional[Set[object]]:
        """Cluster tags whose residency spill must NOT evict on behalf
        of ``tenant``: for every OTHER tenant with a guaranteed floor,
        enough of its prefetch residency (whole clusters, in lease
        order) to keep its held pages at or above the floor.  A tenant
        under its floor is protected entirely; one over its floor
        exposes only the excess as spill victims — so an eviction can
        never dig a tenant below its reservation, and everything it
        frees is genuinely usable by the requester (pages below the
        victim's floor would be withheld from the requester anyway).
        None when the pool has no tenant shares (legacy behaviour)."""
        if not self.pool.tenant_shares:
            return None
        protect: Set[object] = set()
        for t, share in self.pool.tenant_shares.items():
            if t == tenant or share.floor_pages <= 0:
                continue
            kept = 0
            for lease in self.pool.leases.values():
                if lease.owner != "prefetch" or lease.tenant != t:
                    continue
                if kept >= share.floor_pages:
                    break
                protect.add(lease.tag)
                kept += lease.num_pages
        return protect or None

    def commit(self, ticket: AdmissionTicket) -> int:
        """Return the ticket's unconsumed headroom (pages) after dispatch."""
        if ticket.reservation is None:
            return 0
        return self.pool.cancel(ticket.reservation)

    def holds_pending_release(self) -> bool:
        """True iff some current holder will free pages through a future
        event: a pinned prefetch lease (another wave in flight), any
        non-prefetch (e.g. KV) lease, or an outstanding reservation."""
        if self.pool.reservations:
            return True
        return any(l.refcount > 1 or l.owner != "prefetch"
                   for l in self.pool.leases.values())

    # -- parking (waves waiting on page-free events) ------------------------
    def park(self, key: object, npages: int,
             tenant: str = "shared") -> None:
        """Record a stalled wave (``key``) waiting for ``npages`` to
        become free; ``tenant`` keeps the resume stats attributable.
        ``key`` is opaque to the controller: the runtime parks a
        dynamically-formed wave object (whose members re-enter the
        ready set individually on resume) or a ``(cohort, round)``
        tuple in never-re-form mode."""
        self.parked.append((key, int(npages), tenant))

    def unpark_all(self) -> List[Tuple[object, int]]:
        """Hand every parked wave back to the caller for a retry (the
        retry re-enters ``admit``, so order and fairness live there)."""
        out, self.parked = self.parked, []
        self.stats.resumed += len(out)
        for _key, npages, tenant in out:
            self._tstats(tenant).resumed += 1
            self._record("admission.resume", "parked", npages, 0, tenant)
        return [(key, npages) for key, npages, _tenant in out]
