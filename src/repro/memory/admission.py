"""Admission control over the shared device page pool.

A wave's lookahead plan must *reserve* its page headroom up front; the
alternative — the planner silently clamping the plan to whatever slots
happen to be free — is exactly the failure mode the ROADMAP names ("the
planner stalls when a prior wave fills the buffer").  The controller
makes the reserve/stall/spill decision explicit:

  1. **reserve** — if the pool can promise the pages, hand back a
     ticket wrapping a ``Reservation``; allocation consumes it and
     ``commit()`` returns the unused remainder.
  2. **spill** — under pressure, first reclaim *evictable* pages (cold,
     unpinned cluster residency) through the spill hook, then retry the
     reservation.  Spilling is a recorded decision, not a side effect.
  3. **stall** — if pressure comes from pages that future events will
     free (another wave's pins, KV leases, outstanding reservations),
     return ``None``: the caller parks the wave ``PRESSURE_STALLED`` on
     the runtime's event queue and retries on page-free events.
  4. **cap** — when *nothing* outstanding will ever free pages (the
     plan simply exceeds the pool), grant what exists and mark the
     ticket ``capped`` so telemetry shows the shortfall.  This is the
     progress guarantee: a stall with no possible waker would deadlock.

The controller never moves bytes itself; it only arbitrates the pool.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

from repro.memory.pool import DevicePagePool, Reservation


@dataclass
class AdmissionStats:
    admitted: int = 0                # tickets granted with full headroom
    stalled: int = 0                 # admit() refusals that parked a wave
    resumed: int = 0                 # parked waves re-admitted later
    capped: int = 0                  # tickets granted below the request
    spilled_pages: int = 0           # pages reclaimed by the spill hook
    shortfall_pages: int = 0         # requested-minus-granted across caps


@dataclass(eq=False)
class AdmissionTicket:
    """One granted admission: the wave may allocate up to its
    reservation; ``commit()`` after dispatch returns the remainder."""

    ticket_id: int
    owner: str
    pages_requested: int
    pages_granted: int
    reservation: Optional[Reservation]
    capped: bool = False
    spilled_pages: int = 0


class AdmissionController:
    def __init__(self, pool: DevicePagePool, *,
                 spill: Optional[Callable[[int], None]] = None):
        """``spill(target_free_pages)`` should try to raise the pool's
        physically-free page count to the target by evicting cold,
        unpinned residency (best effort)."""
        self.pool = pool
        self.spill = spill
        self.stats = AdmissionStats()
        self._ids = itertools.count()
        self.parked: List[Tuple[object, int]] = []   # (key, pages_requested)

    # -- decision -----------------------------------------------------------
    def admit(self, npages: int, owner: str, *,
              can_wait: bool = True) -> Optional[AdmissionTicket]:
        """Reserve ``npages`` of headroom.  None = park and retry on a
        page-free event (only when ``can_wait`` and a future free is
        possible); otherwise the grant may be spilled-into or capped."""
        npages = int(npages)
        res = self.pool.reserve(npages, owner)
        spilled = 0
        if res is None and self.spill is not None and npages > 0:
            before = self.pool.free_pages()
            # target enough physical frees to cover others' reservations too
            self.spill(npages + self.pool.reserved_pages())
            spilled = self.pool.free_pages() - before
            self.stats.spilled_pages += spilled
            res = self.pool.reserve(npages, owner)
        if res is None:
            if can_wait and self.holds_pending_release():
                self.stats.stalled += 1
                return None
            granted = max(0, self.pool.reservable_pages())
            res = self.pool.reserve(granted, owner) if granted else None
            self.stats.capped += 1
            self.stats.shortfall_pages += npages - granted
            return AdmissionTicket(
                ticket_id=next(self._ids), owner=owner,
                pages_requested=npages, pages_granted=granted,
                reservation=res, capped=True, spilled_pages=spilled)
        self.stats.admitted += 1
        return AdmissionTicket(
            ticket_id=next(self._ids), owner=owner, pages_requested=npages,
            pages_granted=npages, reservation=res, spilled_pages=spilled)

    def commit(self, ticket: AdmissionTicket) -> int:
        """Return the ticket's unconsumed headroom after dispatch."""
        if ticket.reservation is None:
            return 0
        return self.pool.cancel(ticket.reservation)

    def holds_pending_release(self) -> bool:
        """True iff some current holder will free pages through a future
        event: a pinned prefetch lease (another wave in flight), any
        non-prefetch (e.g. KV) lease, or an outstanding reservation."""
        if self.pool.reservations:
            return True
        return any(l.refcount > 1 or l.owner != "prefetch"
                   for l in self.pool.leases.values())

    # -- parking (waves waiting on page-free events) ------------------------
    def park(self, key: object, npages: int) -> None:
        self.parked.append((key, int(npages)))

    def unpark_all(self) -> List[Tuple[object, int]]:
        """Hand every parked wave back to the caller for a retry (the
        retry re-enters ``admit``, so order and fairness live there)."""
        out, self.parked = self.parked, []
        self.stats.resumed += len(out)
        return out
