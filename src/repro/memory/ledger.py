"""Byte-accurate device-memory ledger (one per replica).

Every byte of HBM a replica spends is charged to a named category —
``"prefetch"`` (cluster pages in the shared slab), ``"kv"`` (decode
cache leases), ``"weights"`` (resident model parameters), or any tag a
caller invents — and credited back when the holder releases it.  The
ledger is pure accounting: it never allocates, so it can also track
state the ``DevicePagePool`` does not own (weights live outside the
slab but still compete for the same HBM).

The scheduler reads ``occupancy()`` to route micro-batches away from
memory-loaded replicas, and the serve drivers print ``snapshot()`` as
telemetry.  Charges are exact byte counts (a KV lease is charged its
tensor bytes, not its page-rounded slab footprint), which is what makes
``KVCacheManager.nbytes`` testable against the ledger to the byte.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional


@dataclass
class MemoryLedger:
    """Per-replica byte accounting across memory categories."""

    capacity_bytes: Optional[int] = None     # None => unbounded (no occupancy)
    charges: Dict[str, int] = field(default_factory=dict)
    peak_bytes: int = 0

    def charge(self, category: str, nbytes: int) -> None:
        if nbytes < 0:
            raise ValueError(f"negative charge: {nbytes}")
        self.charges[category] = self.charges.get(category, 0) + int(nbytes)
        self.peak_bytes = max(self.peak_bytes, self.total_bytes())

    def credit(self, category: str, nbytes: int) -> None:
        held = self.charges.get(category, 0)
        if nbytes > held:
            raise ValueError(
                f"credit {nbytes} exceeds {category} charge {held}")
        self.charges[category] = held - int(nbytes)

    def bytes_of(self, category: str) -> int:
        return self.charges.get(category, 0)

    def total_bytes(self) -> int:
        return sum(self.charges.values())

    def occupancy(self) -> float:
        """Fraction of capacity in use (0.0 when capacity is unknown)."""
        if not self.capacity_bytes:
            return 0.0
        return min(1.0, self.total_bytes() / self.capacity_bytes)

    def snapshot(self) -> Dict[str, int]:
        """Telemetry view: per-category bytes + totals (stable keys)."""
        out = {k: v for k, v in sorted(self.charges.items())}
        out["total"] = self.total_bytes()
        out["peak"] = self.peak_bytes
        if self.capacity_bytes:
            out["capacity"] = int(self.capacity_bytes)
        return out
