"""Byte-accurate device-memory ledger (one per replica).

Every byte of HBM a replica spends is charged to a named category —
``"prefetch"`` (cluster pages in the shared slab), ``"kv"`` (decode
cache leases), ``"weights"`` (resident model parameters), or any tag a
caller invents — and credited back when the holder releases it.  The
ledger is pure accounting: it never allocates, so it can also track
state the ``DevicePagePool`` does not own (weights live outside the
slab but still compete for the same HBM).

Charges may additionally carry a **tenant**: the pool mirrors each
lease's tenant here, so multi-tenant serving can read byte-accurate
per-tenant residency (``tenant_bytes``) next to the per-category view.
The sentinel tenant ``"shared"`` (untenanted holders: KV buckets,
direct callers) is not tracked per-tenant — only real tenants appear
in ``snapshot()`` under ``tenant:<name>`` keys.

The scheduler reads ``occupancy()`` to route micro-batches away from
memory-loaded replicas, and the serve drivers print ``snapshot()`` as
telemetry.  Charges are exact byte counts (a KV lease is charged its
tensor bytes, not its page-rounded slab footprint), which is what makes
``KVCacheManager.nbytes`` testable against the ledger to the byte.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional


@dataclass
class MemoryLedger:
    """Per-replica byte accounting across memory categories (and,
    when the pool is multi-tenant, across tenants).  All quantities
    are exact bytes."""

    capacity_bytes: Optional[int] = None     # None => unbounded (no occupancy)
    charges: Dict[str, int] = field(default_factory=dict)
    tenant_charges: Dict[str, int] = field(default_factory=dict)
    peak_bytes: int = 0

    def charge(self, category: str, nbytes: int, *,
               tenant: Optional[str] = None) -> None:
        """Add ``nbytes`` to ``category`` (and to ``tenant``'s total
        when given and not the ``"shared"`` sentinel); updates the peak."""
        if nbytes < 0:
            raise ValueError(f"negative charge: {nbytes}")
        self.charges[category] = self.charges.get(category, 0) + int(nbytes)
        if tenant is not None and tenant != "shared":
            self.tenant_charges[tenant] = (self.tenant_charges.get(tenant, 0)
                                           + int(nbytes))
        self.peak_bytes = max(self.peak_bytes, self.total_bytes())

    def credit(self, category: str, nbytes: int, *,
               tenant: Optional[str] = None) -> None:
        """Return ``nbytes`` previously charged to ``category`` (and to
        ``tenant`` when given); over-crediting raises."""
        held = self.charges.get(category, 0)
        if nbytes > held:
            raise ValueError(
                f"credit {nbytes} exceeds {category} charge {held}")
        self.charges[category] = held - int(nbytes)
        if tenant is not None and tenant != "shared":
            t_held = self.tenant_charges.get(tenant, 0)
            if nbytes > t_held:
                raise ValueError(f"credit {nbytes} exceeds tenant "
                                 f"{tenant!r} charge {t_held}")
            self.tenant_charges[tenant] = t_held - int(nbytes)

    def bytes_of(self, category: str) -> int:
        """Current bytes charged to ``category``."""
        return self.charges.get(category, 0)

    def tenant_bytes(self, tenant: str) -> int:
        """Current bytes attributed to ``tenant`` across all categories
        (0 for unknown tenants and for the ``"shared"`` sentinel)."""
        return self.tenant_charges.get(tenant, 0)

    def total_bytes(self) -> int:
        """Sum of all category charges (bytes)."""
        return sum(self.charges.values())

    def occupancy(self) -> float:
        """Fraction of capacity in use (0.0 when capacity is unknown)."""
        if not self.capacity_bytes:
            return 0.0
        return min(1.0, self.total_bytes() / self.capacity_bytes)

    def snapshot(self) -> Dict[str, int]:
        """Telemetry view: per-category bytes + totals (stable keys);
        per-tenant bytes appear as ``tenant:<name>`` keys when any
        tenant has ever been charged."""
        out = {k: v for k, v in sorted(self.charges.items())}
        for t, v in sorted(self.tenant_charges.items()):
            out[f"tenant:{t}"] = v
        out["total"] = self.total_bytes()
        out["peak"] = self.peak_bytes
        if self.capacity_bytes:
            out["capacity"] = int(self.capacity_bytes)
        return out
