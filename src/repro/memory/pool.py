"""Shared HBM page pool: one slab allocator for every device-memory
consumer of a replica.

TeleRAG's premise is serving RAG *under limited GPU memory*, so carving
HBM into per-subsystem islands (a fixed prefetch slab here, an ad-hoc
KV pool there) wastes exactly the resource the paper economizes.  The
``DevicePagePool`` is the single arbiter: a slab of ``num_pages``
fixed-size device page slots plus a host-side free list, handed out as
refcounted **leases** (vLLM-style block tables — a lease's ``slots``
are its block table, in allocation order, not necessarily contiguous).

Two lease classes share the one free list:

  * **slot leases** (``lease_slots``) — cluster pages for the prefetch
    buffer; their payload is written through ONE fused donated scatter
    per update (``scatter``), the JAX analogue of an async DMA burst;
  * **byte leases** (``lease_bytes``) — KV/decode caches; their tensors
    live outside the slab but their HBM footprint is charged here by
    taking whole page slots out of circulation (``page_cluster`` stays
    -1, so the search kernels never see them).

**Reservations** let an admission controller promise headroom to a wave
before any page is touched: ``reserve()`` subtracts from
``reservable_pages()`` without moving slots; allocation under the
reservation consumes it; ``cancel()`` returns the unused remainder.

**Tenant shares** make the pool multi-tenant: each lease/reservation is
tagged with the tenant it serves, and ``set_tenant_share`` registers a
guaranteed page *floor* (held back from every other tenant while
unclaimed) plus an optional *burst cap* (``max_pages``).  With no
shares registered every tenant sees the legacy single-tenant pool —
``reservable_pages_for`` degrades to ``reservable_pages`` exactly.

Every alloc/free is mirrored into the replica's ``MemoryLedger`` (exact
bytes, not page-rounded, when the caller knows them) and broadcast to
``subscribe``d listeners — the runtime turns those callbacks into
page-free events that wake ``PRESSURE_STALLED`` requests.
"""

from __future__ import annotations

import functools
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.datastore import PagedClusters
from repro.memory.ledger import MemoryLedger
from repro.obs.recorder import FlightRecorder, PoolEvent


@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def _scatter_pages(pages, page_ids, page_cluster, slots, new_pages, new_ids,
                   new_clusters):
    """One fused slab update; out-of-range slot indices are dropped (padding)."""
    pages = pages.at[slots].set(new_pages.astype(pages.dtype), mode="drop")
    page_ids = page_ids.at[slots].set(new_ids, mode="drop")
    page_cluster = page_cluster.at[slots].set(new_clusters, mode="drop")
    return pages, page_ids, page_cluster


def _round_up_pow2(n: int, lo: int = 8) -> int:
    r = lo
    while r < n:
        r *= 2
    return r


class PoolExhausted(RuntimeError):
    """Raised when a caller demands pages the pool cannot supply.

    ``bytes_needed`` > 0 marks a *pool-bytes* shortfall — one that
    evicting cold unpinned prefetch residency could cure (the runtime
    spills toward it before shedding a decode wave).  Structural
    exhaustion (e.g. a KV slab's free list) leaves it 0: no eviction
    can help, only a future release."""

    def __init__(self, msg: str, *, bytes_needed: int = 0):
        super().__init__(msg)
        self.bytes_needed = bytes_needed


@dataclass(eq=False)
class PageLease:
    """A refcounted hold on pool pages. ``slots`` is the block table."""

    lease_id: int
    owner: str                       # ledger category: "prefetch" | "kv" | ...
    slots: Tuple[int, ...]
    nbytes: int                      # exact bytes charged to the ledger
    tag: object = None               # caller-meaningful id (cluster, request)
    refcount: int = 1
    tenant: str = "shared"           # tenant the pages are attributed to

    @property
    def num_pages(self) -> int:
        """Pages held by this lease (length of its block table)."""
        return len(self.slots)


@dataclass(eq=False)
class Reservation:
    """Admission headroom: pages promised but not yet allocated."""

    res_id: int
    owner: str
    pages: int                       # remaining unconsumed headroom
    tenant: str = "shared"           # tenant the headroom is charged to

    def __repr__(self) -> str:       # short form for event logs
        return (f"Reservation({self.res_id}, {self.owner!r}, "
                f"pages={self.pages}, tenant={self.tenant!r})")


@dataclass(frozen=True)
class TenantShare:
    """One tenant's pool entitlement (pages, not bytes).

    ``floor_pages`` is a guaranteed reservation floor: while the tenant
    holds fewer pages than its floor, the shortfall is withheld from
    every other tenant's reservable headroom, so the floor can always
    be claimed.  ``max_pages`` is the burstable cap — the most the
    tenant may hold in total (``None`` = may burst to the whole pool).
    """

    tenant: str
    floor_pages: int
    max_pages: Optional[int] = None


class DevicePagePool:
    """One replica's HBM slab allocator: ``num_pages`` fixed-size page
    slots handed out as refcounted leases (block tables), with
    admission reservations and per-tenant floors/caps layered on the
    same free list.  All byte quantities are exact bytes; all counts
    returned by ``*_pages`` methods are whole page slots."""

    def __init__(self, paged: PagedClusters, num_pages: int,
                 dtype=jnp.bfloat16, *, ledger: Optional[MemoryLedger] = None):
        """Build a pool of ``num_pages`` device page slots over ``paged``
        (which fixes the page geometry and therefore ``page_nbytes``);
        ``ledger`` defaults to a fresh byte ledger sized to the slab."""
        self.paged = paged
        self.num_pages = num_pages
        self.dtype = dtype
        ps, d = paged.page_size, paged.dim
        self.pages = jnp.zeros((num_pages, ps, d), dtype)
        self.page_ids = jnp.full((num_pages, ps), -1, jnp.int32)
        self.page_cluster = jnp.full((num_pages,), -1, jnp.int32)
        self.free: List[int] = list(range(num_pages - 1, -1, -1))
        self.ledger = ledger if ledger is not None else MemoryLedger(
            capacity_bytes=num_pages * self.page_nbytes)
        self.leases: Dict[int, PageLease] = {}
        self.reservations: Dict[int, Reservation] = {}
        self.tenant_shares: Dict[str, TenantShare] = {}
        # running per-tenant held-page counters (leases + unconsumed
        # reservations), maintained incrementally so reserve/lease stay
        # O(1) instead of scanning every lease per allocation
        self._tenant_held: Dict[str, int] = {}
        self._ids = itertools.count()
        self._subscribers: List[Callable[[int], None]] = []
        # flight-recorder lane (attached by the owning engine/server);
        # events are stamped at recorder.now — the pool has no clock
        self.recorder: Optional[FlightRecorder] = None
        self.replica_id = -1

    def _record(self, kind: str, owner: str, pages: int, nbytes: int,
                tenant: str) -> None:
        """Emit one allocation edge with post-op free/occupancy (the
        exporters' pool counter tracks read these)."""
        rec = self.recorder
        if rec is not None:
            rec.emit(PoolEvent(
                t=rec.now, kind=kind, replica=self.replica_id,
                tenant=tenant, owner=owner, pages=pages, nbytes=nbytes,
                free_pages=len(self.free),
                occupancy=self.ledger.occupancy()))

    def _bump_tenant(self, tenant: str, delta: int) -> None:
        if delta:
            self._tenant_held[tenant] = (self._tenant_held.get(tenant, 0)
                                         + delta)

    # -- capacity -----------------------------------------------------------
    @property
    def page_nbytes(self) -> int:
        """Bytes per page slot (fixed by the paged datastore geometry)."""
        return self.paged.page_nbytes()

    @property
    def capacity_bytes(self) -> int:
        """Total slab bytes (``num_pages * page_nbytes``)."""
        return self.num_pages * self.page_nbytes

    def free_pages(self) -> int:
        """Physically free slots (some may be spoken for by reservations)."""
        return len(self.free)

    @property
    def used_pages(self) -> int:
        """Slots currently out on leases (pages, not bytes)."""
        return self.num_pages - len(self.free)

    def reserved_pages(self) -> int:
        """Unconsumed headroom promised to outstanding reservations."""
        return sum(r.pages for r in self.reservations.values())

    def reservable_pages(self) -> int:
        """Free slots not already promised to an outstanding reservation."""
        return len(self.free) - self.reserved_pages()

    def leased_pages(self, owner: Optional[str] = None) -> int:
        """Pages out on leases, optionally filtered by ledger category."""
        return sum(l.num_pages for l in self.leases.values()
                   if owner is None or l.owner == owner)

    # -- tenant shares ------------------------------------------------------
    def set_tenant_share(self, tenant: str, floor_pages: int,
                         max_pages: Optional[int] = None) -> TenantShare:
        """Register (or replace) ``tenant``'s entitlement: a guaranteed
        ``floor_pages`` reservation floor plus an optional ``max_pages``
        burst cap.  The sum of floors must fit the pool."""
        share = TenantShare(tenant=tenant, floor_pages=int(floor_pages),
                            max_pages=(None if max_pages is None
                                       else int(max_pages)))
        if share.max_pages is not None and share.max_pages < share.floor_pages:
            raise ValueError(f"max_pages {share.max_pages} < floor "
                             f"{share.floor_pages} for tenant {tenant!r}")
        others = sum(s.floor_pages for t, s in self.tenant_shares.items()
                     if t != tenant)
        if others + share.floor_pages > self.num_pages:
            raise ValueError(
                f"tenant floors exceed the pool: {others} + "
                f"{share.floor_pages} > {self.num_pages} pages")
        self.tenant_shares[tenant] = share
        return share

    def tenant_pages(self, tenant: str) -> int:
        """Pages ``tenant`` currently holds: its live leases plus its
        outstanding (unconsumed) reservation headroom.  O(1) — read off
        the incrementally-maintained counter."""
        return self._tenant_held.get(tenant, 0)

    def tenant_bytes(self, tenant: str,
                     owner: Optional[str] = None) -> int:
        """Bytes of ``tenant``'s live leases, optionally filtered by
        ledger category (``owner="kv"`` = the tenant's decode-cache
        footprint — what ``ServerTelemetry.tenants`` surfaces)."""
        return sum(l.nbytes for l in self.leases.values()
                   if l.tenant == tenant
                   and (owner is None or l.owner == owner))

    def reattribute(self, lease: PageLease, tenant: str) -> PageLease:
        """Move a live lease's tenancy (held-page counters + ledger
        attribution) to ``tenant`` — how a recycled KV bucket's bytes
        follow the request that reuses it instead of staying charged to
        its first owner."""
        if lease.lease_id not in self.leases:
            raise KeyError(f"lease {lease.lease_id} is not live")
        if lease.tenant == tenant:
            return lease
        self._bump_tenant(lease.tenant, -lease.num_pages)
        self._bump_tenant(tenant, lease.num_pages)
        self.ledger.credit(lease.owner, lease.nbytes, tenant=lease.tenant)
        lease.tenant = tenant
        self.ledger.charge(lease.owner, lease.nbytes, tenant=tenant)
        return lease

    def withheld_floor_pages(self, tenant: str) -> int:
        """Pages held back from ``tenant``: the unclaimed part of every
        OTHER tenant's guaranteed floor (``max(0, floor - held)``)."""
        return sum(max(0, s.floor_pages - self.tenant_pages(t))
                   for t, s in self.tenant_shares.items() if t != tenant)

    def tenant_ceiling(self, tenant: str = "shared") -> int:
        """The most pages ``tenant`` could EVER reserve in one request,
        assuming every current holder releases: the pool minus other
        tenants' guaranteed floors, bounded by the tenant's own burst
        cap.  A request above this can never be granted — admission
        must cap it rather than park it waiting for frees that cannot
        suffice."""
        ceiling = self.num_pages - sum(
            s.floor_pages for t, s in self.tenant_shares.items()
            if t != tenant)
        share = self.tenant_shares.get(tenant)
        if share is not None and share.max_pages is not None:
            ceiling = min(ceiling, share.max_pages)
        return max(0, ceiling)

    def reservable_pages_for(self, tenant: str = "shared") -> int:
        """``reservable_pages`` as seen by ``tenant``: free slots minus
        outstanding reservations, minus other tenants' unclaimed floors,
        capped by the tenant's own burst cap.  With no shares registered
        this is exactly ``reservable_pages()``."""
        if not self.tenant_shares:
            return self.reservable_pages()
        avail = self.reservable_pages() - self.withheld_floor_pages(tenant)
        share = self.tenant_shares.get(tenant)
        if share is not None and share.max_pages is not None:
            avail = min(avail, share.max_pages - self.tenant_pages(tenant))
        return max(0, avail)

    def subscribe(self, cb: Callable[[int], None]) -> None:
        """``cb(pages_freed)`` fires whenever slots return to the free list."""
        self._subscribers.append(cb)

    def subscribers(self) -> Tuple[Callable[[int], None], ...]:
        """The registered page-free listeners (read-only view)."""
        return tuple(self._subscribers)

    def rebind_subscribers(self, source: "DevicePagePool") -> int:
        """Carry page-free listeners over from a replaced pool (replica
        restart): long-lived runtimes subscribed to the old pool keep
        receiving events from this one.  Returns how many were bound."""
        bound = 0
        for cb in source.subscribers():
            if cb not in self._subscribers:
                self._subscribers.append(cb)
                bound += 1
        return bound

    def _notify_freed(self, pages: int) -> None:
        if pages > 0:
            for cb in self._subscribers:
                cb(pages)

    # -- reservations -------------------------------------------------------
    def reserve(self, npages: int, owner: str,
                tenant: str = "shared") -> Optional[Reservation]:
        """Promise ``npages`` of headroom to ``owner`` on behalf of
        ``tenant`` (None = the tenant's view of the pool cannot cover
        it: free slots minus others' reservations and unclaimed floors,
        bounded by the tenant's burst cap)."""
        if npages > self.reservable_pages_for(tenant):
            return None
        res = Reservation(res_id=next(self._ids), owner=owner,
                          pages=int(npages), tenant=tenant)
        self.reservations[res.res_id] = res
        self._bump_tenant(tenant, int(npages))
        return res

    def cancel(self, res: Reservation) -> int:
        """Release a reservation's unconsumed headroom; returns it."""
        live = self.reservations.pop(res.res_id, None)
        if live is None:
            return 0
        remainder, live.pages = live.pages, 0
        self._bump_tenant(live.tenant, -remainder)
        self._notify_freed(remainder)
        return remainder

    # -- leases -------------------------------------------------------------
    def _take_slots(self, npages: int, reservation: Optional[Reservation],
                    tenant: str) -> Optional[List[int]]:
        if reservation is not None and reservation.res_id in self.reservations:
            headroom = self.reservable_pages_for(tenant) + reservation.pages
        else:
            reservation = None
            headroom = self.reservable_pages_for(tenant)
        if npages > headroom or npages > len(self.free):
            return None
        if reservation is not None:
            consumed = min(reservation.pages, npages)
            reservation.pages -= consumed
            self._bump_tenant(reservation.tenant, -consumed)
        return [self.free.pop() for _ in range(npages)]

    def lease_slots(self, npages: int, owner: str = "prefetch", *,
                    tag: object = None, nbytes: Optional[int] = None,
                    reservation: Optional[Reservation] = None,
                    tenant: Optional[str] = None) -> Optional[PageLease]:
        """Lease scatterable page slots (cluster pages). None = no room.
        ``tenant`` defaults to the reservation's tenant (a wave's lease
        inherits the tenancy its admission reserved under)."""
        if tenant is None:
            tenant = reservation.tenant if reservation is not None else "shared"
        slots = self._take_slots(npages, reservation, tenant)
        if slots is None:
            return None
        nb = npages * self.page_nbytes if nbytes is None else int(nbytes)
        lease = PageLease(lease_id=next(self._ids), owner=owner,
                         slots=tuple(slots), nbytes=nb, tag=tag,
                         tenant=tenant)
        self.leases[lease.lease_id] = lease
        self._bump_tenant(tenant, npages)
        self.ledger.charge(owner, nb, tenant=tenant)
        self._record("pool.lease", owner, npages, nb, tenant)
        return lease

    def lease_bytes(self, nbytes: int, owner: str = "kv", *,
                    tag: object = None,
                    reservation: Optional[Reservation] = None,
                    tenant: Optional[str] = None) -> Optional[PageLease]:
        """Charge an HBM footprint that lives outside the slab (KV cache):
        whole page slots leave circulation, the ledger is charged the
        exact byte count."""
        npages = -(-int(nbytes) // self.page_nbytes)
        return self.lease_slots(npages, owner, tag=tag, nbytes=int(nbytes),
                                reservation=reservation, tenant=tenant)

    def retain(self, lease: PageLease) -> PageLease:
        """Take one more reference on a live lease (wave pinning)."""
        if lease.lease_id not in self.leases:
            raise KeyError(f"lease {lease.lease_id} is not live")
        lease.refcount += 1
        return lease

    def release(self, lease: PageLease) -> int:
        """Drop one reference; at zero the slots return to the free list.
        Returns the number of pages freed (0 while references remain)."""
        if lease.lease_id not in self.leases:
            return 0
        lease.refcount -= 1
        if lease.refcount > 0:
            return 0
        del self.leases[lease.lease_id]
        self.free.extend(lease.slots)
        self._bump_tenant(lease.tenant, -lease.num_pages)
        self.ledger.credit(lease.owner, lease.nbytes, tenant=lease.tenant)
        self._record("pool.release", lease.owner, lease.num_pages,
                     lease.nbytes, lease.tenant)
        self._notify_freed(lease.num_pages)
        return lease.num_pages

    # -- device slab --------------------------------------------------------
    def scatter(self, slot_list: Sequence[int], np_pages: Sequence[np.ndarray],
                np_ids: Sequence[np.ndarray], np_cl: Sequence[int]) -> None:
        """One fused donated update of the slab (pow-2 bucketed sizes so
        recompiles stay bounded); out-of-range slots are padding."""
        n = len(slot_list)
        if n == 0:
            return
        cap = _round_up_pow2(n)
        slots_arr = np.full(cap, self.num_pages, np.int32)   # OOB = dropped
        slots_arr[:n] = list(slot_list)
        pages_arr = np.zeros((cap, self.paged.page_size, self.paged.dim),
                             np.float32)
        pages_arr[:n] = np.stack(np_pages)
        ids_arr = np.full((cap, self.paged.page_size), -1, np.int32)
        ids_arr[:n] = np.stack(np_ids)
        cl_arr = np.full(cap, -1, np.int32)
        cl_arr[:n] = list(np_cl)
        # async dispatch: device_put + scatter overlap with LLM decode
        self.pages, self.page_ids, self.page_cluster = _scatter_pages(
            self.pages, self.page_ids, self.page_cluster,
            jnp.asarray(slots_arr), jnp.asarray(pages_arr),
            jnp.asarray(ids_arr), jnp.asarray(cl_arr))

    def device_view(self):
        """The (pages, page_ids, page_cluster) device arrays the search
        kernels read (page_cluster -1 marks unsearchable slots)."""
        return self.pages, self.page_ids, self.page_cluster
