"""Shared HBM page pool: one slab allocator for every device-memory
consumer of a replica.

TeleRAG's premise is serving RAG *under limited GPU memory*, so carving
HBM into per-subsystem islands (a fixed prefetch slab here, an ad-hoc
KV pool there) wastes exactly the resource the paper economizes.  The
``DevicePagePool`` is the single arbiter: a slab of ``num_pages``
fixed-size device page slots plus a host-side free list, handed out as
refcounted **leases** (vLLM-style block tables — a lease's ``slots``
are its block table, in allocation order, not necessarily contiguous).

Two lease classes share the one free list:

  * **slot leases** (``lease_slots``) — cluster pages for the prefetch
    buffer; their payload is written through ONE fused donated scatter
    per update (``scatter``), the JAX analogue of an async DMA burst;
  * **byte leases** (``lease_bytes``) — KV/decode caches; their tensors
    live outside the slab but their HBM footprint is charged here by
    taking whole page slots out of circulation (``page_cluster`` stays
    -1, so the search kernels never see them).

**Reservations** let an admission controller promise headroom to a wave
before any page is touched: ``reserve()`` subtracts from
``reservable_pages()`` without moving slots; allocation under the
reservation consumes it; ``cancel()`` returns the unused remainder.

Every alloc/free is mirrored into the replica's ``MemoryLedger`` (exact
bytes, not page-rounded, when the caller knows them) and broadcast to
``subscribe``d listeners — the runtime turns those callbacks into
page-free events that wake ``PRESSURE_STALLED`` requests.
"""

from __future__ import annotations

import functools
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.datastore import PagedClusters
from repro.memory.ledger import MemoryLedger


@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def _scatter_pages(pages, page_ids, page_cluster, slots, new_pages, new_ids,
                   new_clusters):
    """One fused slab update; out-of-range slot indices are dropped (padding)."""
    pages = pages.at[slots].set(new_pages.astype(pages.dtype), mode="drop")
    page_ids = page_ids.at[slots].set(new_ids, mode="drop")
    page_cluster = page_cluster.at[slots].set(new_clusters, mode="drop")
    return pages, page_ids, page_cluster


def _round_up_pow2(n: int, lo: int = 8) -> int:
    r = lo
    while r < n:
        r *= 2
    return r


class PoolExhausted(RuntimeError):
    """Raised when a caller demands pages the pool cannot supply."""


@dataclass(eq=False)
class PageLease:
    """A refcounted hold on pool pages. ``slots`` is the block table."""

    lease_id: int
    owner: str                       # ledger category: "prefetch" | "kv" | ...
    slots: Tuple[int, ...]
    nbytes: int                      # exact bytes charged to the ledger
    tag: object = None               # caller-meaningful id (cluster, request)
    refcount: int = 1

    @property
    def num_pages(self) -> int:
        return len(self.slots)


@dataclass(eq=False)
class Reservation:
    """Admission headroom: pages promised but not yet allocated."""

    res_id: int
    owner: str
    pages: int                       # remaining unconsumed headroom

    def __repr__(self) -> str:       # short form for event logs
        return f"Reservation({self.res_id}, {self.owner!r}, pages={self.pages})"


class DevicePagePool:
    def __init__(self, paged: PagedClusters, num_pages: int,
                 dtype=jnp.bfloat16, *, ledger: Optional[MemoryLedger] = None):
        self.paged = paged
        self.num_pages = num_pages
        self.dtype = dtype
        ps, d = paged.page_size, paged.dim
        self.pages = jnp.zeros((num_pages, ps, d), dtype)
        self.page_ids = jnp.full((num_pages, ps), -1, jnp.int32)
        self.page_cluster = jnp.full((num_pages,), -1, jnp.int32)
        self.free: List[int] = list(range(num_pages - 1, -1, -1))
        self.ledger = ledger if ledger is not None else MemoryLedger(
            capacity_bytes=num_pages * self.page_nbytes)
        self.leases: Dict[int, PageLease] = {}
        self.reservations: Dict[int, Reservation] = {}
        self._ids = itertools.count()
        self._subscribers: List[Callable[[int], None]] = []

    # -- capacity -----------------------------------------------------------
    @property
    def page_nbytes(self) -> int:
        return self.paged.page_nbytes()

    @property
    def capacity_bytes(self) -> int:
        return self.num_pages * self.page_nbytes

    def free_pages(self) -> int:
        """Physically free slots (some may be spoken for by reservations)."""
        return len(self.free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self.free)

    def reserved_pages(self) -> int:
        return sum(r.pages for r in self.reservations.values())

    def reservable_pages(self) -> int:
        """Free slots not already promised to an outstanding reservation."""
        return len(self.free) - self.reserved_pages()

    def leased_pages(self, owner: Optional[str] = None) -> int:
        return sum(l.num_pages for l in self.leases.values()
                   if owner is None or l.owner == owner)

    def subscribe(self, cb: Callable[[int], None]) -> None:
        """``cb(pages_freed)`` fires whenever slots return to the free list."""
        self._subscribers.append(cb)

    def subscribers(self) -> Tuple[Callable[[int], None], ...]:
        """The registered page-free listeners (read-only view)."""
        return tuple(self._subscribers)

    def rebind_subscribers(self, source: "DevicePagePool") -> int:
        """Carry page-free listeners over from a replaced pool (replica
        restart): long-lived runtimes subscribed to the old pool keep
        receiving events from this one.  Returns how many were bound."""
        bound = 0
        for cb in source.subscribers():
            if cb not in self._subscribers:
                self._subscribers.append(cb)
                bound += 1
        return bound

    def _notify_freed(self, pages: int) -> None:
        if pages > 0:
            for cb in self._subscribers:
                cb(pages)

    # -- reservations -------------------------------------------------------
    def reserve(self, npages: int, owner: str) -> Optional[Reservation]:
        if npages > self.reservable_pages():
            return None
        res = Reservation(res_id=next(self._ids), owner=owner,
                          pages=int(npages))
        self.reservations[res.res_id] = res
        return res

    def cancel(self, res: Reservation) -> int:
        """Release a reservation's unconsumed headroom; returns it."""
        live = self.reservations.pop(res.res_id, None)
        if live is None:
            return 0
        remainder, live.pages = live.pages, 0
        self._notify_freed(remainder)
        return remainder

    # -- leases -------------------------------------------------------------
    def _take_slots(self, npages: int, reservation: Optional[Reservation],
                    ) -> Optional[List[int]]:
        if reservation is not None and reservation.res_id in self.reservations:
            headroom = self.reservable_pages() + reservation.pages
        else:
            reservation = None
            headroom = self.reservable_pages()
        if npages > headroom or npages > len(self.free):
            return None
        if reservation is not None:
            reservation.pages = max(0, reservation.pages - npages)
        return [self.free.pop() for _ in range(npages)]

    def lease_slots(self, npages: int, owner: str = "prefetch", *,
                    tag: object = None, nbytes: Optional[int] = None,
                    reservation: Optional[Reservation] = None,
                    ) -> Optional[PageLease]:
        """Lease scatterable page slots (cluster pages). None = no room."""
        slots = self._take_slots(npages, reservation)
        if slots is None:
            return None
        nb = npages * self.page_nbytes if nbytes is None else int(nbytes)
        lease = PageLease(lease_id=next(self._ids), owner=owner,
                         slots=tuple(slots), nbytes=nb, tag=tag)
        self.leases[lease.lease_id] = lease
        self.ledger.charge(owner, nb)
        return lease

    def lease_bytes(self, nbytes: int, owner: str = "kv", *,
                    tag: object = None,
                    reservation: Optional[Reservation] = None,
                    ) -> Optional[PageLease]:
        """Charge an HBM footprint that lives outside the slab (KV cache):
        whole page slots leave circulation, the ledger is charged the
        exact byte count."""
        npages = -(-int(nbytes) // self.page_nbytes)
        return self.lease_slots(npages, owner, tag=tag, nbytes=int(nbytes),
                                reservation=reservation)

    def retain(self, lease: PageLease) -> PageLease:
        if lease.lease_id not in self.leases:
            raise KeyError(f"lease {lease.lease_id} is not live")
        lease.refcount += 1
        return lease

    def release(self, lease: PageLease) -> int:
        """Drop one reference; at zero the slots return to the free list.
        Returns the number of pages freed (0 while references remain)."""
        if lease.lease_id not in self.leases:
            return 0
        lease.refcount -= 1
        if lease.refcount > 0:
            return 0
        del self.leases[lease.lease_id]
        self.free.extend(lease.slots)
        self.ledger.credit(lease.owner, lease.nbytes)
        self._notify_freed(lease.num_pages)
        return lease.num_pages

    # -- device slab --------------------------------------------------------
    def scatter(self, slot_list: Sequence[int], np_pages: Sequence[np.ndarray],
                np_ids: Sequence[np.ndarray], np_cl: Sequence[int]) -> None:
        """One fused donated update of the slab (pow-2 bucketed sizes so
        recompiles stay bounded); out-of-range slots are padding."""
        n = len(slot_list)
        if n == 0:
            return
        cap = _round_up_pow2(n)
        slots_arr = np.full(cap, self.num_pages, np.int32)   # OOB = dropped
        slots_arr[:n] = list(slot_list)
        pages_arr = np.zeros((cap, self.paged.page_size, self.paged.dim),
                             np.float32)
        pages_arr[:n] = np.stack(np_pages)
        ids_arr = np.full((cap, self.paged.page_size), -1, np.int32)
        ids_arr[:n] = np.stack(np_ids)
        cl_arr = np.full(cap, -1, np.int32)
        cl_arr[:n] = list(np_cl)
        # async dispatch: device_put + scatter overlap with LLM decode
        self.pages, self.page_ids, self.page_cluster = _scatter_pages(
            self.pages, self.page_ids, self.page_cluster,
            jnp.asarray(slots_arr), jnp.asarray(pages_arr),
            jnp.asarray(ids_arr), jnp.asarray(cl_arr))

    def device_view(self):
        return self.pages, self.page_ids, self.page_cluster
