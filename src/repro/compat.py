"""Version compatibility shims for the range of jax releases we support.

Keep every cross-version branch here so call sites stay clean:
  * ``shard_map`` — top-level ``jax.shard_map`` (jax >= 0.5, ``check_vma``
    kwarg) vs ``jax.experimental.shard_map`` (older jax, ``check_rep``).
  * ``compiled_cost_analysis`` — ``Compiled.cost_analysis()`` returns a
    dict on new jax and a one-element list of dicts on older releases.

``launch/mesh.py`` holds the matching ``AxisType`` fallback (it must stay
import-light; see that module's docstring).
"""

from __future__ import annotations

from typing import Any, Dict

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` with fallback to the experimental module."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as legacy_shard_map
    return legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_rep=check_vma)


def compiled_cost_analysis(compiled: Any) -> Dict[str, float]:
    """Normalize ``Compiled.cost_analysis()`` to a flat dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca
