"""Post-hoc overlap analysis over a recorded trace.

TeleRAG's efficiency claim is that the lookahead H2D copy hides under
the LLM's pre-retrieval generation window.  This module turns a
``FlightRecorder`` stream into the paper's key numbers:

* **Per-round lookahead overlap ratio** — each retrieving wave member
  models its copy of the wave's transfer from its own round start
  (``dispatch + duration``, the per-request link view of App. C); the
  ratio is the fraction of that copy interval hidden under the
  member's generation span.  1.0 = fully hidden (the TeleRAG ideal),
  0.0 = fully exposed (the sequential baseline).
* **Stall-time attribution** — where non-overlapped time went:
  ``link_s`` (``transfer_wait`` spans: generation ended before the
  copy landed), ``pressure_s`` (``pressure_stall`` spans: parked on
  pool admission), ``queue_s`` (server submit -> replica admit).
* **Wave-fragmentation stats** — dispatched wave sizes (mean,
  singleton fraction): how much batch efficiency the dynamic former
  is recovering or losing.

Pure function of the recorder — no live serving state is touched, so
it runs equally on a just-drained server or a trace re-loaded later.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

from repro.obs.recorder import FlightRecorder


@dataclass(frozen=True)
class OverlapRound:
    """One retrieving member-round's overlap accounting (seconds)."""

    request_id: int
    replica: int
    wave_id: int
    round_index: int
    transfer_s: float                 # the member's modeled copy length
    hidden_s: float                   # |copy interval ∩ generate span|
    wait_s: float                     # transfer_wait after generation

    @property
    def ratio(self) -> float:
        """Fraction of the copy hidden under generation (0 when the
        round moved nothing)."""
        return self.hidden_s / self.transfer_s if self.transfer_s > 0 else 0.0


@dataclass(frozen=True)
class OverlapReport:
    """The analyzer's output: per-round rows plus the aggregates the
    serve drivers print and benches assert on."""

    rounds: List[OverlapRound] = field(default_factory=list)
    stall: Dict[str, float] = field(default_factory=dict)
    wave_sizes: List[int] = field(default_factory=list)
    n_requests: int = 0

    @property
    def prefetched_rounds(self) -> List[OverlapRound]:
        """Rounds that actually moved bytes (demoted/all-hit rounds
        have no copy to hide and are excluded from ratio means)."""
        return [r for r in self.rounds if r.transfer_s > 0]

    @property
    def mean_overlap_ratio(self) -> float:
        pre = self.prefetched_rounds
        return float(np.mean([r.ratio for r in pre])) if pre else 0.0

    @property
    def fully_hidden_frac(self) -> float:
        """Fraction of prefetched rounds whose copy hid entirely."""
        pre = self.prefetched_rounds
        if not pre:
            return 0.0
        return float(np.mean([r.ratio >= 1.0 - 1e-9 for r in pre]))

    @property
    def mean_wave_size(self) -> float:
        return float(np.mean(self.wave_sizes)) if self.wave_sizes else 0.0

    @property
    def singleton_wave_frac(self) -> float:
        if not self.wave_sizes:
            return 0.0
        return float(np.mean([s == 1 for s in self.wave_sizes]))

    def summary(self) -> str:
        """Printable block (what ``launch/serve.py`` appends)."""
        st = self.stall
        return "\n".join([
            f"overlap: {len(self.prefetched_rounds)} prefetched rounds "
            f"(of {len(self.rounds)}), mean hidden "
            f"{self.mean_overlap_ratio:.1%}, fully hidden "
            f"{self.fully_hidden_frac:.1%}",
            f"stalls: link={st.get('link_s', 0.0)*1e3:.1f}ms "
            f"pressure={st.get('pressure_s', 0.0)*1e3:.1f}ms "
            f"queue={st.get('queue_s', 0.0)*1e3:.1f}ms",
            f"waves: {len(self.wave_sizes)} dispatched, mean size "
            f"{self.mean_wave_size:.2f}, singletons "
            f"{self.singleton_wave_frac:.1%}",
        ])


def _intersect(a0: float, a1: float, b0: float, b1: float) -> float:
    """Length of [a0,a1] ∩ [b0,b1] (0 when disjoint)."""
    return max(0.0, min(a1, b1) - max(a0, b0))


def analyze(rec: FlightRecorder) -> OverlapReport:
    """Compute the overlap report from a recorded trace."""
    # wave dispatch -> its lookahead transfer correlation
    wave_transfer: Dict[Tuple[int, int], int] = {}
    wave_sizes: List[int] = []
    for ev in rec.of("wave.dispatch"):
        wave_sizes.append(ev.size)
        if ev.transfer_id >= 0:
            wave_transfer[(ev.replica, ev.wave_id)] = ev.transfer_id
    transfers = {(ev.replica, ev.transfer_id): ev
                 for ev in rec.of("transfer.issue")}

    # per-member spans, keyed (replica, request, round)
    gen: Dict[Tuple[int, int, int], Tuple[float, float, int]] = {}
    wait: Dict[Tuple[int, int, int], float] = {}
    pressure_s = 0.0
    for ev in rec.of("span"):
        key = (ev.replica, ev.request_id, ev.round_index)
        if ev.name == "generate":
            gen[key] = (ev.t, ev.t + ev.dur, ev.wave_id)
        elif ev.name == "transfer_wait":
            wait[key] = wait.get(key, 0.0) + ev.dur
        elif ev.name == "pressure_stall":
            pressure_s += ev.dur

    rounds: List[OverlapRound] = []
    for (replica, rid, rnd), (g0, g1, wid) in sorted(gen.items()):
        tid = wave_transfer.get((replica, wid), -1)
        tr = transfers.get((replica, tid))
        dur = (tr.end_t - tr.start_t) if tr is not None else 0.0
        # per-request link view: the member models the copy from its own
        # round start (== its generate start; lookahead dispatches at the
        # frontier) for the transfer's duration
        hidden = _intersect(g0, g0 + dur, g0, g1) if dur > 0 else 0.0
        rounds.append(OverlapRound(
            request_id=rid, replica=replica, wave_id=wid, round_index=rnd,
            transfer_s=dur, hidden_s=hidden,
            wait_s=wait.get((replica, rid, rnd), 0.0)))

    # queue attribution: server-side submit -> replica admit, per request
    submit_t: Dict[int, float] = {}
    admit_t: Dict[int, float] = {}
    complete = 0
    for ev in rec.of("request"):
        if ev.label == "submit" and ev.request_id not in submit_t:
            submit_t[ev.request_id] = ev.t
        elif ev.label == "admit" and ev.request_id not in admit_t:
            admit_t[ev.request_id] = ev.t
        elif ev.label == "complete":
            complete += 1
    queue_s = sum(max(0.0, admit_t[r] - t) for r, t in submit_t.items()
                  if r in admit_t)

    return OverlapReport(
        rounds=rounds,
        stall={"link_s": sum(w for w in wait.values()),
               "pressure_s": pressure_s, "queue_s": queue_s},
        wave_sizes=wave_sizes,
        n_requests=len(admit_t))
