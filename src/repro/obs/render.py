"""The ONE telemetry text renderer.

``ServerTelemetry.summary()`` / ``TenantTelemetry.line()`` used to
format themselves inline in ``serving/api.py``, and the two row types
had drifted: replica rows printed percentages at ``.1%`` and megabytes
at ``.1f`` while tenant rows truncated to ``.0%`` / mixed ``.2f`` —
so a 99.5% attainment printed as ``100%`` while the replica one line up
showed ``99.5%``.  All telemetry printing now goes through the shared
formatters here (same precision on every row), and the serving
dataclasses delegate.

Duck-typed on purpose: the functions read the public telemetry fields
(``repro.obs`` never imports from ``repro.serving``).
"""

from __future__ import annotations

from typing import List


def fmt_pct(x: float) -> str:
    """Uniform percentage rendering (one decimal, every row type)."""
    return f"{x:.1%}"


def fmt_mb(nbytes: float) -> str:
    """Uniform megabyte rendering (two decimals, every row type)."""
    return f"{nbytes / 1e6:.2f}MB"


def fmt_ms(seconds: float) -> str:
    """Uniform millisecond rendering (one decimal)."""
    return f"{seconds * 1e3:.1f}ms"


def render_replica_line(r) -> str:
    """One replica's row (a ``ReplicaTelemetry``)."""
    led = r.ledger
    return (f"replica {r.replica}: h2d={fmt_mb(r.bytes_h2d)} "
            f"cache_hit={fmt_pct(r.cache_hit_rate)} "
            f"occ={fmt_pct(r.occupancy)} "
            f"prefetch={fmt_mb(led.get('prefetch', 0))} "
            f"kv={fmt_mb(led.get('kv', 0))} "
            f"peak={led.get('peak', 0) / 1e9:.2f}GB "
            f"transfers={r.transfers} "
            f"(queued {fmt_ms(r.transfer_queued_s)})")


def render_tenant_line(t) -> str:
    """One tenant's row (a ``TenantTelemetry``)."""
    return (f"tenant {t.tenant}: {t.completed} done "
            f"p50={fmt_ms(t.p50_latency_s)} "
            f"p99={fmt_ms(t.p99_latency_s)} "
            f"queue_mean={fmt_ms(t.mean_queue_s)} "
            f"attain={fmt_pct(t.attainment)} "
            f"miss={t.deadline_missed} "
            f"(queue {t.missed_in_queue} / "
            f"service {t.missed_in_service}) "
            f"stall={fmt_ms(t.stall_s)} "
            f"demoted={t.demoted_rounds} "
            f"kv={fmt_mb(t.kv_bytes)}")


def render_telemetry(st) -> str:
    """The full multi-line snapshot (a ``ServerTelemetry``): fleet
    totals, one row per replica, one row per tenant — every row through
    the same formatters."""
    lines: List[str] = [
        f"server: {st.completed} completed / {st.waves} waves / "
        f"{st.dispatched_batches} micro-batches, "
        f"clock={fmt_ms(st.clock_s)}, "
        f"h2d={fmt_mb(st.bytes_h2d)}, "
        f"admission admitted={st.admission_admitted} "
        f"stalled={st.admission_stalled} "
        f"spilled_pages={st.spilled_pages}"]
    lines.extend("  " + render_replica_line(r) for r in st.replicas)
    lines.extend("  " + render_tenant_line(t) for t in st.tenants)
    return "\n".join(lines)
