"""The flight recorder: one structured event bus for the serving stack.

Every subsystem emits typed ``TraceEvent``s — request lifecycle marks,
timeline spans, wave form/dispatch/complete, transfer issue/land,
admission decisions, pool lease/release, decode steps, counter samples
— stamped on the **shared event clock** (modeled seconds, the same
clock ``RetrievalRuntime`` and ``TeleRAGServer`` advance).  One
``FlightRecorder`` serves a whole ``TeleRAGServer``: every replica
engine's components are attached to it with their replica id, so
cross-replica correlation (which wave, which tenant, which request)
is a filter over one stream instead of a join across ad-hoc logs.

Clock discipline: components deep in the stack (the pool, the
admission controller) do not receive ``now`` — they stamp events at
``recorder.now``, which the runtime advances via ``tick()`` at every
event-loop step.  Events may therefore be *appended* slightly out of
``t`` order (a wave's completion is emitted at schedule time with its
future timestamp); consumers that need time order use
``sorted_events()``.

``legacy_tuples()`` is the compatibility shim for the retired
``RetrievalRuntime.event_log`` list: the same ``(t, label,
request_id)`` 3-tuples, in emission order, filtered to one replica's
lane — existing tests and benches keep iterating it unchanged.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# the request-lifecycle labels the retired ``runtime.event_log`` carried;
# ``legacy_tuples()`` reproduces exactly these (a server-side "submit"
# mark is NOT one of them — it never appeared in the legacy log)
LEGACY_LABELS = frozenset({
    "admit", "prefetch", "generate", "retrieve", "complete",
    "pressure_stall", "pressure_resume", "prefetch_demoted",
})


@dataclass(frozen=True)
class TraceEvent:
    """Base event: a kind, a stamp on the shared event clock (seconds),
    and the correlation ids every consumer filters by.  ``replica=-1``
    means "not attached to a replica" (a standalone engine, or the
    server itself); ``request_id``/``wave_id`` are -1 when the event is
    not about one request/wave."""

    t: float
    kind: str
    replica: int = -1
    request_id: int = -1
    wave_id: int = -1
    tenant: str = "shared"


@dataclass(frozen=True)
class RequestEvent(TraceEvent):
    """One request-lifecycle mark (``kind="request"``): ``label`` is
    the lifecycle step (``submit`` / ``admit`` / ``prefetch`` /
    ``generate`` / ``retrieve`` / ``complete`` / ``pressure_stall`` /
    ``pressure_resume`` / ``prefetch_demoted``)."""

    label: str = ""
    round_index: int = -1


@dataclass(frozen=True)
class SpanEvent(TraceEvent):
    """One request-timeline interval (``kind="span"``): mirrors the
    ``Span`` appended to ``RequestRecord.timeline`` (``name`` is the
    span kind, ``t`` its start, ``dur`` its length — 0 for instants)."""

    name: str = ""
    dur: float = 0.0
    round_index: int = -1


@dataclass(frozen=True)
class WaveEvent(TraceEvent):
    """One wave-lifecycle mark: ``wave.form`` when the executor takes
    the wave up, ``wave.dispatch`` when it actually executes (a parked
    wave forms but never dispatches — it dissolves and its members ride
    a later wave), ``wave.complete`` at its last member's scheduled
    round end.  ``transfer_id`` correlates the dispatch with the wave's
    lookahead copy (-1 = no prefetch moved)."""

    size: int = 0
    request_ids: Tuple[int, ...] = ()
    rounds: Tuple[int, ...] = ()
    transfer_id: int = -1
    nbytes: int = 0


@dataclass(frozen=True)
class TransferRecord(TraceEvent):
    """One H2D copy on the modeled link: ``transfer.issue`` at submit,
    ``transfer.land`` at its modeled completion (emitted at schedule
    time with the future stamp).  Mirrors ``TransferEvent``."""

    transfer_id: int = -1
    nbytes: int = 0
    n_clusters: int = 0
    channel: int = -1
    start_t: float = 0.0
    end_t: float = 0.0
    transfer_kind: str = "prefetch"


@dataclass(frozen=True)
class AdmissionEvent(TraceEvent):
    """One admission decision: ``admission.admit`` (full headroom),
    ``admission.stall`` (parked ``PRESSURE_STALLED``),
    ``admission.cap`` (granted below the request),
    ``admission.spill`` (the spill hook reclaimed pages), or
    ``admission.resume`` (a parked wave woken by a page-free)."""

    owner: str = ""
    pages_requested: int = 0
    pages_granted: int = 0
    spilled_pages: int = 0


@dataclass(frozen=True)
class PoolEvent(TraceEvent):
    """One page-pool allocation edge: ``pool.lease`` / ``pool.release``
    with the post-op free-page count and ledger occupancy — the
    exporters' counter tracks (pool free pages, ledger occupancy,
    per-tenant KV bytes) are derived from this stream."""

    owner: str = ""                   # ledger category: prefetch | kv | ...
    pages: int = 0
    nbytes: int = 0
    free_pages: int = 0
    occupancy: float = 0.0


@dataclass(frozen=True)
class KVEvent(TraceEvent):
    """One decode-cache lease edge (``kv.acquire`` / ``kv.append`` /
    ``kv.splice`` / ``kv.release`` / ``kv.drop``): the KV manager's view
    on top of the pool's byte accounting.  Dense bucket leases emit
    acquire/release with ``lease_id=-1`` (``recycled=True`` when the
    acquire reused a released bucket instead of allocating, and
    ``kv.drop`` when a recycled bucket's bytes finally return to the
    pool — together these keep the checker's kv accounting
    conservation-exact across bucket recycling); paged (block-table)
    leases additionally carry a globally unique ``lease_id``, their slab
    page count (``pages``) and — on every ``kv.append`` — the
    post-append max sequence ``length``, which is what the invariant
    checker conserves (page conservation per lease,
    append-within-lease ordering, no append past ``max_len``).
    ``kv.splice`` marks precomputed chunk-KV pages attached to an open
    paged lease by block-table edit: ``pages`` spliced page slots,
    ``length`` the post-splice max length, ``max_len`` the lease's
    raised capacity."""

    batch: int = 0
    max_len: int = 0
    nbytes: int = 0
    lease_id: int = -1                # paged leases only; -1 = dense bucket
    pages: int = 0                    # slab page slots held by the lease
    length: int = 0                   # kv.append: max lengths after the write
    recycled: bool = False            # dense acquire reused a released bucket


@dataclass(frozen=True)
class ChunkKVEvent(TraceEvent):
    """One chunk-KV residency edge (``chunk.load`` / ``chunk.pin`` /
    ``chunk.unpin`` / ``chunk.evict``): the lifecycle of one document's
    precomputed KV pages on device.  ``chunk.load`` lands ``pages``
    slab pages H2D (charged to the pool as owner ``"chunk_kv"``);
    ``chunk.pin``/``chunk.unpin`` bracket a wave's splice (``pinned``
    is the post-op pin count — pinned residency is protected from
    spill); ``chunk.evict`` returns the pages (legal only at
    ``pinned == 0``).  The invariant checker conserves pages per
    (replica, doc) and rejects pin-before-load (the splice-before-land
    race) and evict-while-pinned."""

    doc_id: int = -1
    pages: int = 0
    nbytes: int = 0
    pinned: int = 0                   # pin count after this event


@dataclass(frozen=True)
class DecodeStep(TraceEvent):
    """One observed decode outcome (``kind="decode"``): the hook ran
    ``tokens`` real steps in ``seconds`` measured wall clock for a wave
    of ``batch`` (mirrors ``DecodeEvent``, which drives the clock)."""

    tokens: int = 0
    seconds: float = 0.0
    batch: int = 0


@dataclass(frozen=True)
class CounterSample(TraceEvent):
    """One sampled scalar (``kind="counter"``) for exporter counter
    tracks the pool stream cannot derive (e.g. per-replica queue
    depth)."""

    name: str = ""
    value: float = 0.0


@dataclass
class FlightRecorder:
    """Append-only typed event log on the shared event clock.

    ``now`` is the recorder's clock cursor, advanced monotonically by
    ``tick()`` from whichever runtime is stepping — it is what
    emitters without a ``now`` of their own (pool, admission) stamp
    with.  ``capacity`` bounds memory for long-lived servers: when
    exceeded, the oldest half of the log is dropped (a flight recorder
    keeps the recent past; ``dropped`` counts the loss so analyzers
    can report a truncated window instead of silently lying)."""

    capacity: Optional[int] = None
    now: float = 0.0
    events: List[TraceEvent] = field(default_factory=list)
    dropped: int = 0

    def tick(self, t: float) -> float:
        """Advance the clock cursor (monotone); returns the cursor."""
        if t > self.now:
            self.now = t
        return self.now

    def emit(self, ev: TraceEvent) -> TraceEvent:
        """Append one event (also advances ``now`` to the event's stamp
        when it is ahead — emitters schedule future completions)."""
        self.events.append(ev)
        if self.capacity is not None and len(self.events) > self.capacity:
            drop = len(self.events) // 2
            del self.events[:drop]
            self.dropped += drop
        return ev

    # -- queries -------------------------------------------------------------
    def of(self, *kinds: str) -> List[TraceEvent]:
        """Events whose kind is one of ``kinds`` (emission order)."""
        want = set(kinds)
        return [e for e in self.events if e.kind in want]

    def for_request(self, request_id: int) -> List[TraceEvent]:
        """Every event correlated to one request (emission order)."""
        return [e for e in self.events if e.request_id == request_id]

    def sorted_events(self) -> List[TraceEvent]:
        """All events in event-clock order (stable for equal stamps)."""
        return sorted(self.events, key=lambda e: e.t)

    def request_marks(self, request_id: int) -> Dict[str, float]:
        """label -> first event-clock time, over one request's
        lifecycle marks (the admit<=dispatch<=complete ordering check
        reads this)."""
        out: Dict[str, float] = {}
        for e in self.events:
            if (e.kind == "request" and e.request_id == request_id
                    and e.label not in out):
                out[e.label] = e.t
        return out

    def legacy_tuples(self, replica: Optional[int] = None,
                      ) -> List[Tuple[float, str, int]]:
        """The retired ``runtime.event_log`` view: ``(t, label,
        request_id)`` tuples in emission order, filtered to one
        replica's lane (None = all lanes) and to the labels the legacy
        log carried."""
        return [(e.t, e.label, e.request_id) for e in self.events
                if e.kind == "request" and e.label in LEGACY_LABELS
                and (replica is None or e.replica == replica)]

    def clear(self) -> None:
        """Drop all events (the clock cursor is kept — it is shared
        with live runtimes and must stay monotone)."""
        self.events.clear()
        self.dropped = 0
