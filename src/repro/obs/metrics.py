"""Label-keyed metrics registry: counters, gauges, histograms, series.

``TeleRAGServer``'s telemetry dataclasses are *views* over this
registry: the server's lifetime counts (completed / waves / batches)
and every per-tenant SLO accumulator live here as first-class
instruments, keyed by ``(name, labels)`` — so the future autoscaler
and the telemetry snapshot read the same numbers.  Occupancy and
attainment are additionally sampled as ``TimeSeries`` (time-stamped on
the shared event clock), which is what a control loop needs instead of
an end-of-run scalar.

Numerically this is a refactor, not a change: ``Histogram.percentile``
is ``np.percentile`` over the raw samples, exactly what the pre-registry
``_TenantAcc`` computed — the snapshot values are pinned equal (1e-6)
by tests/test_obs.py and the existing tests/test_slo.py assertions.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Tuple

import numpy as np

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


@dataclass
class Counter:
    """Monotone accumulator (float so second-valued sums fit too)."""

    name: str
    labels: LabelKey = ()
    value: float = 0.0

    def inc(self, n: float = 1.0) -> float:
        self.value += n
        return self.value


@dataclass
class Gauge:
    """Last-write-wins scalar."""

    name: str
    labels: LabelKey = ()
    value: float = 0.0

    def set(self, v: float) -> float:
        self.value = float(v)
        return self.value


@dataclass
class Histogram:
    """Raw-sample histogram: keeps every observation so percentiles are
    exact (``np.percentile``), matching the pre-registry accumulators
    bit-for-bit at serving scales."""

    name: str
    labels: LabelKey = ()
    samples: List[float] = field(default_factory=list)

    def observe(self, v: float) -> None:
        self.samples.append(float(v))

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def sum(self) -> float:
        return float(np.sum(self.samples)) if self.samples else 0.0

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.samples else 0.0

    def percentile(self, q: float) -> float:
        """``np.percentile`` over the raw samples (0 when empty)."""
        if not self.samples:
            return 0.0
        return float(np.percentile(np.asarray(self.samples), q))


@dataclass
class TimeSeries:
    """(t, value) samples on the shared event clock — the consumable
    form of occupancy/attainment for control loops."""

    name: str
    labels: LabelKey = ()
    samples: List[Tuple[float, float]] = field(default_factory=list)

    def sample(self, t: float, v: float) -> None:
        self.samples.append((float(t), float(v)))

    def sorted_samples(self) -> List[Tuple[float, float]]:
        """Samples in event-clock order (emission can be post-hoc)."""
        return sorted(self.samples)

    @property
    def last(self) -> float:
        """Most recent value on the clock (0 when never sampled)."""
        s = self.sorted_samples()
        return s[-1][1] if s else 0.0


class MetricsRegistry:
    """Get-or-create instrument store keyed by ``(name, labels)``."""

    def __init__(self) -> None:
        self._counters: Dict[Tuple[str, LabelKey], Counter] = {}
        self._gauges: Dict[Tuple[str, LabelKey], Gauge] = {}
        self._histograms: Dict[Tuple[str, LabelKey], Histogram] = {}
        self._series: Dict[Tuple[str, LabelKey], TimeSeries] = {}

    def counter(self, name: str, **labels: object) -> Counter:
        key = (name, _label_key(labels))
        if key not in self._counters:
            self._counters[key] = Counter(name, key[1])
        return self._counters[key]

    def gauge(self, name: str, **labels: object) -> Gauge:
        key = (name, _label_key(labels))
        if key not in self._gauges:
            self._gauges[key] = Gauge(name, key[1])
        return self._gauges[key]

    def histogram(self, name: str, **labels: object) -> Histogram:
        key = (name, _label_key(labels))
        if key not in self._histograms:
            self._histograms[key] = Histogram(name, key[1])
        return self._histograms[key]

    def series(self, name: str, **labels: object) -> TimeSeries:
        key = (name, _label_key(labels))
        if key not in self._series:
            self._series[key] = TimeSeries(name, key[1])
        return self._series[key]

    def label_values(self, name: str, label: str) -> List[str]:
        """Distinct values one label takes across all instruments of
        ``name`` (e.g. every tenant a histogram was observed for)."""
        out = []
        for store in (self._counters, self._gauges,
                      self._histograms, self._series):
            for (n, lk) in store:
                for k, v in lk:
                    if n == name and k == label and v not in out:
                        out.append(v)
        return sorted(out)

    def collect(self) -> List[Dict[str, object]]:
        """Flat dump of every instrument (export / debugging)."""
        rows: List[Dict[str, object]] = []
        for (name, lk), c in self._counters.items():
            rows.append({"type": "counter", "name": name,
                         "labels": dict(lk), "value": c.value})
        for (name, lk), g in self._gauges.items():
            rows.append({"type": "gauge", "name": name,
                         "labels": dict(lk), "value": g.value})
        for (name, lk), h in self._histograms.items():
            rows.append({"type": "histogram", "name": name,
                         "labels": dict(lk), "count": h.count,
                         "sum": h.sum,
                         "p50": h.percentile(50), "p99": h.percentile(99)})
        for (name, lk), s in self._series.items():
            rows.append({"type": "series", "name": name,
                         "labels": dict(lk), "samples": len(s.samples),
                         "last": s.last})
        return rows

    def items(self) -> Iterable[Tuple[str, object]]:
        """Every (name, instrument) pair across the four stores."""
        for store in (self._counters, self._gauges,
                      self._histograms, self._series):
            for (name, _lk), inst in store.items():
                yield name, inst
