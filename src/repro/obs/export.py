"""Trace exporters: Chrome/Perfetto ``trace_event`` JSON and JSONL.

The Perfetto export lays one *process* per replica (pid = replica
index; the server's own events land on a synthetic "server" process)
with one *thread track per lane*:

  tid 1  decode      — ``generate`` / ``generate_tail`` spans
  tid 2  link        — H2D transfers (issue->land) + ``transfer_wait``
  tid 3  retrieval   — ``retrieve`` spans (+ zero-length dispatches)
  tid 4  admission   — ``pressure_stall`` spans, admission instants

Requests are **async spans** (``ph: b``/``e``, cat ``request``, id =
request id) from admit to complete, so Perfetto draws each request's
life as one arrow-connected track regardless of which lane its rounds
ran on.  Counter tracks (``ph: C``) are derived from the recorder
stream: ``ledger_occupancy`` and ``pool_free_pages`` from pool
lease/release edges, ``kv_bytes`` per tenant from KV-category pool
edges, ``queue_depth`` from server samples.

Timestamps: the event clock is seconds; Chrome wants microseconds
(``ts`` / ``dur``).  Load the file at https://ui.perfetto.dev or
chrome://tracing.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

from repro.obs.recorder import FlightRecorder, TraceEvent

_US = 1e6
_SERVER_PID = 9999                    # replica=-1 events (server lane)

_LANES = {"decode": 1, "link": 2, "retrieval": 3, "admission": 4}
_SPAN_LANE = {
    "generate": "decode", "generate_tail": "decode",
    "transfer_wait": "link",
    "retrieve": "retrieval", "prefetch_dispatch": "retrieval",
    "pressure_stall": "admission",
}


def _pid(ev: TraceEvent) -> int:
    return ev.replica if ev.replica >= 0 else _SERVER_PID


def to_perfetto(rec: FlightRecorder) -> Dict[str, object]:
    """Render the recorder into a Chrome ``trace_event`` document."""
    out: List[Dict[str, object]] = []
    pids = sorted({_pid(e) for e in rec.events} | {_SERVER_PID})
    for pid in pids:
        name = "server" if pid == _SERVER_PID else f"replica {pid}"
        out.append({"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                    "args": {"name": name}})
        for lane, tid in _LANES.items():
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "args": {"name": lane}})

    # running per-(pid, tenant) KV bytes, rebuilt from pool edges
    kv_bytes: Dict[int, Dict[str, float]] = {}
    for ev in rec.sorted_events():
        pid = _pid(ev)
        ts = ev.t * _US
        if ev.kind == "span":
            lane = _SPAN_LANE.get(ev.name)
            if lane is None:          # admit/complete instants ride the
                continue              # async request span instead
            out.append({"ph": "X", "name": ev.name, "pid": pid,
                        "tid": _LANES[lane], "ts": ts,
                        "dur": max(0.0, ev.dur) * _US, "cat": "span",
                        "args": {"request_id": ev.request_id,
                                 "round": ev.round_index,
                                 "wave_id": ev.wave_id,
                                 "tenant": ev.tenant}})
        elif ev.kind == "request":
            if ev.label == "admit":
                out.append({"ph": "b", "cat": "request",
                            "id": ev.request_id,
                            "name": f"req {ev.request_id}", "pid": pid,
                            "tid": _LANES["decode"], "ts": ts,
                            "args": {"tenant": ev.tenant}})
            elif ev.label == "complete":
                out.append({"ph": "e", "cat": "request",
                            "id": ev.request_id,
                            "name": f"req {ev.request_id}", "pid": pid,
                            "tid": _LANES["decode"], "ts": ts})
            elif ev.label in ("pressure_stall", "pressure_resume",
                              "prefetch_demoted", "submit"):
                out.append({"ph": "i", "name": ev.label, "pid": pid,
                            "tid": _LANES["admission"], "ts": ts,
                            "s": "t",
                            "args": {"request_id": ev.request_id}})
        elif ev.kind == "transfer.issue":
            out.append({"ph": "X", "name": f"h2d {ev.transfer_kind}",
                        "pid": pid, "tid": _LANES["link"],
                        "ts": ev.start_t * _US,
                        "dur": max(0.0, ev.end_t - ev.start_t) * _US,
                        "cat": "transfer",
                        "args": {"transfer_id": ev.transfer_id,
                                 "nbytes": ev.nbytes,
                                 "clusters": ev.n_clusters,
                                 "channel": ev.channel,
                                 "queued_us": (ev.start_t - ev.t) * _US}})
        elif ev.kind in ("pool.lease", "pool.release"):
            out.append({"ph": "C", "name": "pool_free_pages", "pid": pid,
                        "ts": ts, "args": {"free": ev.free_pages}})
            out.append({"ph": "C", "name": "ledger_occupancy", "pid": pid,
                        "ts": ts, "args": {"occupancy": ev.occupancy}})
            if ev.owner == "kv":
                per = kv_bytes.setdefault(pid, {})
                delta = ev.nbytes if ev.kind == "pool.lease" else -ev.nbytes
                per[ev.tenant] = per.get(ev.tenant, 0.0) + delta
                out.append({"ph": "C", "name": "kv_bytes", "pid": pid,
                            "ts": ts, "args": dict(per)})
        elif ev.kind == "counter":
            out.append({"ph": "C", "name": ev.name, "pid": pid, "ts": ts,
                        "args": {"value": ev.value}})
        elif ev.kind.startswith("wave."):
            # transfer_id / request_ids make the instant replayable by
            # the happens-before checker (repro.analysis.invariants)
            out.append({"ph": "i", "name": ev.kind, "pid": pid,
                        "tid": _LANES["retrieval"], "ts": ts, "s": "t",
                        "args": {"wave_id": ev.wave_id, "size": ev.size,
                                 "transfer_id": ev.transfer_id,
                                 "nbytes": ev.nbytes,
                                 "request_ids": list(ev.request_ids)}})
        elif ev.kind.startswith("admission."):
            out.append({"ph": "i", "name": ev.kind, "pid": pid,
                        "tid": _LANES["admission"], "ts": ts, "s": "t",
                        "args": {"owner": ev.owner,
                                 "wave_id": ev.wave_id,
                                 "pages_requested": ev.pages_requested,
                                 "pages_granted": ev.pages_granted}})
        elif ev.kind == "decode":
            out.append({"ph": "i", "name": "decode_step", "pid": pid,
                        "tid": _LANES["decode"], "ts": ts, "s": "t",
                        "args": {"request_id": ev.request_id,
                                 "tokens": ev.tokens,
                                 "seconds": ev.seconds,
                                 "batch": ev.batch}})
    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": {"schema": "telerag.trace/v1",
                          "dropped_events": rec.dropped}}


def write_trace(rec: FlightRecorder, path: str) -> str:
    """Write the Perfetto JSON document to ``path``; returns it."""
    with open(path, "w") as f:
        json.dump(to_perfetto(rec), f)
    return path


def to_jsonl(rec: FlightRecorder) -> List[str]:
    """One JSON object per raw event (typed: ``event`` holds the
    dataclass name), in emission order — the lossless stream form."""
    lines = []
    for ev in rec.events:
        d = dataclasses.asdict(ev)
        d["event"] = type(ev).__name__
        lines.append(json.dumps(d))
    return lines


def write_jsonl(rec: FlightRecorder, path: str) -> str:
    """Write the JSONL stream to ``path``; returns it."""
    with open(path, "w") as f:
        for line in to_jsonl(rec):
            f.write(line + "\n")
    return path


def load_jsonl(path: str) -> List[Dict[str, object]]:
    """Parse a JSONL stream back into plain dicts (analysis tooling)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
