"""Injectable clock sources: the wall-clock discipline boundary.

Everything inside the deterministic core (serving / memory / core /
obs) times itself on the **shared event clock** — modeled seconds the
runtimes advance. The few places that look like they need real wall
time (scheduler overhead sampling in ``TeleRAGServer._route_wave``,
host-search calibration in ``TeleRAGEngine.calibrate_tcc``) take one
of these clock objects instead of calling ``time.perf_counter()``
directly, so:

  * default runs are **replay-deterministic** — the same inputs give
    the same trace, byte for byte (``EventClock`` reads the flight
    recorder's cursor, which only moves with modeled events);
  * real measurement is an explicit opt-in at the launch layer
    (``launch/serve.py`` injects ``SystemClock``), not an ambient
    side effect;
  * telint's TL002 rule can keep a one-file allowlist: this module is
    the single sanctioned ``time`` import in the core.
"""

from __future__ import annotations

import time
from typing import Optional


class SystemClock:
    """Real wall time.  The ONE sanctioned ``time.perf_counter`` call
    site inside the deterministic core (telint TL002 allowlists this
    file) — inject it where real measurement is wanted."""

    #: real clocks measure; deterministic ones return modeled/zero time
    real = True

    def perf(self) -> float:
        return time.perf_counter()


class EventClock:
    """Deterministic clock: reads the flight recorder's event-clock
    cursor (modeled seconds).  Two ``perf()`` calls bracketing host
    work return the same value — elapsed wall time is 0.0 by design,
    so consumers that *measure* must either accept the modeled zero
    (``sched_overhead_s`` in replayable runs) or fall back to a
    modeled estimate (``calibrate_tcc``)."""

    real = False

    def __init__(self, recorder: Optional[object] = None):
        self.recorder = recorder

    def perf(self) -> float:
        rec = self.recorder
        return float(getattr(rec, "now", 0.0)) if rec is not None else 0.0


SYSTEM_CLOCK = SystemClock()
