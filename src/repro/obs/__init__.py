"""Observability: the flight recorder, exporters, metrics registry,
and the post-hoc overlap analyzer.

This package is the READ side of the serving stack: every subsystem
built in PRs 1-6 (runtime, transfer engine, admission controller,
device page pool, KV cache, server) emits typed ``TraceEvent``s into
one ``FlightRecorder`` per server, and everything here consumes that
stream — Perfetto traces (``export``), counters/gauges/histograms
(``metrics``), overlap-efficiency reports (``analyze``), and the
telemetry text renderer (``render``).  Nothing in ``repro.obs`` imports
from ``repro.serving`` (or any other repro subpackage): the emitters
depend on the recorder, never the other way around.
"""

from repro.obs.analyze import OverlapReport, OverlapRound, analyze
from repro.obs.clock import SYSTEM_CLOCK, EventClock, SystemClock
from repro.obs.export import to_jsonl, to_perfetto, write_jsonl, write_trace
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               TimeSeries)
from repro.obs.recorder import (LEGACY_LABELS, AdmissionEvent, ChunkKVEvent,
                                CounterSample, DecodeStep, FlightRecorder,
                                KVEvent, PoolEvent, RequestEvent, SpanEvent,
                                TraceEvent, TransferRecord, WaveEvent)
from repro.obs.render import (render_replica_line, render_telemetry,
                              render_tenant_line)

__all__ = [
    "AdmissionEvent", "analyze", "ChunkKVEvent", "Counter", "CounterSample",
    "DecodeStep",
    "EventClock", "SYSTEM_CLOCK", "SystemClock",
    "FlightRecorder", "Gauge", "Histogram", "KVEvent", "LEGACY_LABELS",
    "MetricsRegistry", "OverlapReport", "OverlapRound", "PoolEvent",
    "RequestEvent", "render_replica_line", "render_telemetry",
    "render_tenant_line", "SpanEvent", "TimeSeries", "to_jsonl",
    "to_perfetto", "TraceEvent", "TransferRecord", "WaveEvent",
    "write_jsonl", "write_trace",
]
